"""Network-aware hierarchical aggregation topology (the third actuator).

Every sync so far crossed one flat pod ring, regardless of what the
measured WAN looked like.  This module makes the aggregation *topology* a
schedulable resource alongside tier and interval (HeterPS-style), following
the measured network the way the adaptive-tree literature does: reduce
inside each region first (cheap intra-region fabric), then exchange
between regions over the links the bandwidth beliefs say are worth using —
with an auxiliary two-hop route around a link whose belief has collapsed.

Three layers:

- :class:`TopologySpec` — *what could run*: the region grouping (from
  ``core/scheduler.py``'s plan / ``control_plane.TrainingPlan``) plus the
  shape family (``ring`` — one-peer exchange between region leaders;
  ``tree`` — gather-to-root + broadcast).  ``compile`` turns it into an
  :class:`AggregationSchedule` against the current :class:`LinkBeliefs`:
  ring orderings maximize the bottleneck link, trees root at the
  best-connected region, and a leaf whose direct link to the root has
  collapsed (belief ``collapse_ratio`` below the best relay's bottleneck)
  is routed ``leaf -> relay -> root`` instead.
- :class:`LinkBeliefs` — *what the network looks like*: one
  :class:`~repro.core.autotune.WanProbeEstimator` per inter-region link
  (cliff-snap included, so one transfer on a collapsed link reprices it),
  fed by the transport's billed per-leg transfer times — the per-link
  generalization of the PR-5 :class:`~repro.core.transport.MeasuredWanProbe`.
- :class:`HierarchicalTransport` — *who ships*: a
  :class:`~repro.core.transport.WanTransport` behind the PR-5 seam.
  Shipping delegates to the inline ring (``sync._INLINE_RING``) — the SAME
  code path the legacy jit traces, so flat-ring and hierarchical runs
  produce **bit-identical** averaged parameters by construction; what the
  topology changes is the *billing*: each sync round costs the compiled
  schedule's phase times (intra legs at fabric speed, WAN legs at their
  own link's traced bandwidth through the DES ``transfer_time`` law), and
  the billed per-leg times feed the link beliefs, which recompile the
  schedule for the next round — a collapse observed at round k is routed
  around at round k+1.

:class:`TopologyPlanner` is the actuator head: it prices every candidate
shape against the current beliefs (``estimate_round_s``) and switches with
hysteresis; ``AdaptiveSyncController(topology=planner)`` consults it under
the same EF-convergence guard as the tier/interval laws (a guard trip
defers topology moves — fidelity first).

The existing sync strategies map onto the hierarchy levels (paper
§III.C's inter-PS model averaging): intra-region reduction is an SMA
barrier mean, inter-region exchange is MA gossip —
:func:`repro.core.sync.hierarchical_average` implements the mapping and
its degenerate equivalences (singleton groups == flat ``ama``, one group
== flat ``sma``).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.autotune import WanProbeEstimator
from repro.core.sync import _INLINE_RING, ChunkPayload
from repro.core.transport import (MeasuredWanProbe, TransferRecord,
                                  WanTransport, _StreamRound)
from repro.core.wan import BandwidthTrace, WANConfig, transfer_time

_EPS = 1e-9

TOPOLOGY_KINDS = ("ring", "tree")

Link = Tuple[str, str]


def link_key(a: str, b: str) -> Link:
    """Canonical (sorted) key for the undirected inter-region link a<->b."""
    if a == b:
        raise ValueError(f"no WAN link from region {a!r} to itself")
    return (a, b) if a < b else (b, a)


class LinkBeliefs:
    """Per-link bandwidth beliefs: one cliff-snapping estimator per
    inter-region link, the per-link generalization of
    :class:`~repro.core.transport.MeasuredWanProbe`.

    Links never observed report ``default_mbps`` — schedule compilation
    must be total even before the first transfer."""

    def __init__(self, default_mbps: float = 100.0, alpha: float = 0.5,
                 cliff_snap: float = 4.0):
        if default_mbps <= 0:
            raise ValueError("default_mbps must be positive")
        self.default_mbps = float(default_mbps)
        self.alpha = alpha
        self.cliff_snap = cliff_snap
        self._est: Dict[Link, WanProbeEstimator] = {}

    def observe(self, a: str, b: str, mbps: float) -> None:
        """Fold one achieved-bandwidth sample into the a<->b belief."""
        key = link_key(a, b)
        est = self._est.get(key)
        if est is None:
            est = self._est[key] = WanProbeEstimator(
                alpha=self.alpha, cliff_snap=self.cliff_snap)
        est.observe(float(mbps))

    def mbps(self, a: str, b: str) -> float:
        est = self._est.get(link_key(a, b))
        if est is None or est.bandwidth_mbps is None:
            return self.default_mbps
        return est.bandwidth_mbps

    def snapshot(self) -> Dict[str, float]:
        """``"a|b" -> belief`` for every observed link (bench recording)."""
        return {f"{a}|{b}": round(e.bandwidth_mbps, 6)
                for (a, b), e in sorted(self._est.items())
                if e.bandwidth_mbps is not None}


@dataclass(frozen=True)
class LinkLeg:
    """One directed transfer of an inter-region phase.  ``via`` marks the
    auxiliary route: the payload hops ``src -> via -> src's target`` —
    two sequential WAN transfers instead of one collapsed one."""

    src: str
    dst: str
    via: Optional[str] = None

    @property
    def hops(self) -> Tuple[Link, ...]:
        """The undirected link(s) this leg crosses, in transfer order."""
        if self.via is None:
            return (link_key(self.src, self.dst),)
        return (link_key(self.src, self.via), link_key(self.via, self.dst))


@dataclass(frozen=True)
class Phase:
    """One barrier-separated stage of the schedule.  Legs within a phase
    run in parallel (the phase costs its slowest leg); phases run in
    sequence.  ``wan=False`` phases move bytes on the intra-region fabric
    only."""

    kind: str                      # "intra-reduce" | "exchange" |
    #                                "gather" | "broadcast" | "intra-bcast"
    legs: Tuple[LinkLeg, ...]
    wan: bool = True


@dataclass(frozen=True)
class AggregationSchedule:
    """A compiled two-level aggregation round: which transfers happen, in
    which order, over which links.  This is the *billing and accounting*
    model of a sync round — the data movement itself stays the bit-exact
    inline ring (see :class:`HierarchicalTransport.ship_bucket`)."""

    kind: str
    root: Optional[str]
    phases: Tuple[Phase, ...]

    @property
    def wan_legs(self) -> Tuple[LinkLeg, ...]:
        return tuple(leg for ph in self.phases if ph.wan for leg in ph.legs)

    @property
    def wan_transfers(self) -> int:
        """Payload-sized WAN transfers per sync round (aux legs pay two) —
        the multiplier topology-aware traffic accounting bills instead of
        the flat ring's ``n_pods``."""
        return sum(len(leg.hops) for leg in self.wan_legs)

    @property
    def uses_aux_route(self) -> bool:
        return any(leg.via is not None for leg in self.wan_legs)

    def round_s(self, payload_mb: float, bw_of: Callable[[str, str], float],
                *, intra_mbps: float, wan: Optional[WANConfig] = None,
                rng: Optional[np.random.Generator] = None,
                latency_s: float = 0.0) -> float:
        """Wall-clock of one round shipping ``payload_mb`` per leg.

        With ``wan``/``rng`` each hop is priced by the DES transfer law
        (:func:`repro.core.wan.transfer_time`: latency + seeded lognormal
        fluctuation) at ``bw_of(src, dst)``; without them the estimate is
        deterministic (``payload*8/bw + latency_s`` per hop) — the form
        :class:`TopologyPlanner` compares candidates with.  Intra-region
        legs move at ``intra_mbps`` fabric speed, no WAN latency."""
        total = 0.0
        for phase in self.phases:
            if not phase.legs:
                continue
            if not phase.wan:
                total += payload_mb * 8.0 / intra_mbps
                continue
            slowest = 0.0
            for leg in phase.legs:
                t = 0.0
                for a, b in leg.hops:
                    bw = max(bw_of(a, b), _EPS)
                    if wan is not None:
                        t += transfer_time(payload_mb, bw, wan, rng)
                    else:
                        t += payload_mb * 8.0 / bw + latency_s
                slowest = max(slowest, t)
            total += slowest
        return total


@dataclass(frozen=True)
class TopologySpec:
    """Region grouping + shape family, compiled against link beliefs.

    ``groups`` maps each region to the pod indices it hosts (from the
    scheduler plan: pods sharing a ``CloudResources.region`` aggregate
    locally before anything crosses the WAN).  Singleton groups make the
    intra level a no-op — the schedule is then a pure inter-region ring or
    tree over all pods."""

    kind: str
    groups: Tuple[Tuple[str, Tuple[int, ...]], ...]
    intra_mbps: float = 10_000.0
    collapse_ratio: float = 4.0     # aux route wins when its bottleneck
    #   beats the direct link's belief by this factor — same scale as the
    #   estimator's cliff-snap, so one snapped observation is enough

    def __post_init__(self):
        if self.kind not in TOPOLOGY_KINDS:
            raise ValueError(f"unknown topology kind {self.kind!r}; "
                             f"expected one of {TOPOLOGY_KINDS}")
        if not self.groups:
            raise ValueError("TopologySpec needs at least one region group")
        names = [name for name, _ in self.groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate region names in {names}")
        pods = [i for _, members in self.groups for i in members]
        if not pods or sorted(pods) != list(range(len(pods))):
            raise ValueError(
                f"group members must partition pods 0..n-1, got {pods}")
        if self.intra_mbps <= 0:
            raise ValueError("intra_mbps must be positive")
        if self.collapse_ratio < 1.0:
            raise ValueError("collapse_ratio must be >= 1")

    # ------------------------------------------------------------ factories
    @classmethod
    def from_regions(cls, regions: Sequence[str], kind: str = "tree",
                     **kw) -> "TopologySpec":
        """Group pod ``i`` under ``regions[i]``; pods sharing a region name
        form one intra-region group (order of first appearance)."""
        groups: Dict[str, List[int]] = {}
        for i, r in enumerate(regions):
            groups.setdefault(r, []).append(i)
        return cls(kind=kind,
                   groups=tuple((r, tuple(m)) for r, m in groups.items()),
                   **kw)

    @classmethod
    def from_plan(cls, plan, kind: str = "tree", **kw) -> "TopologySpec":
        """Region grouping from a ``control_plane.TrainingPlan`` (pod i is
        ``resource_plans[i]``; grouping key is its scheduler region)."""
        return cls.from_regions([p.region for p in plan.resource_plans],
                                kind=kind, **kw)

    def with_kind(self, kind: str) -> "TopologySpec":
        return self if kind == self.kind else replace(self, kind=kind)

    # ----------------------------------------------------------- structure
    @property
    def regions(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.groups)

    @property
    def n_pods(self) -> int:
        return sum(len(m) for _, m in self.groups)

    def links(self) -> Tuple[Link, ...]:
        """Every inter-region link, canonical order."""
        return tuple(link_key(a, b)
                     for a, b in itertools.combinations(sorted(self.regions),
                                                        2))

    # ------------------------------------------------------------- compile
    def compile(self, beliefs: LinkBeliefs) -> AggregationSchedule:
        """Two-level schedule against the current beliefs: intra-region
        reduce, inter-region exchange (ring ordered for the best
        bottleneck link / tree rooted at the best-connected region with
        auxiliary routes around collapsed links), intra-region broadcast.
        Deterministic: ties break lexicographically, so the same beliefs
        always compile the same schedule (the replay gate's contract)."""
        regions = self.regions
        intra = tuple(LinkLeg(name, name) for name, members in self.groups
                      if len(members) > 1)
        phases: List[Phase] = []
        if intra:
            phases.append(Phase("intra-reduce", intra, wan=False))
        root: Optional[str] = None
        if len(regions) > 1:
            if self.kind == "ring":
                order = self._ring_order(beliefs)
                legs = tuple(LinkLeg(order[i], order[(i + 1) % len(order)])
                             for i in range(len(order)))
                phases.append(Phase("exchange", legs))
            else:
                root = max(regions, key=lambda r: (
                    sum(beliefs.mbps(r, o) for o in regions if o != r), r))
                gather = tuple(self._route(r, root, regions, beliefs)
                               for r in regions if r != root)
                bcast = tuple(LinkLeg(leg.dst, leg.src, via=leg.via)
                              for leg in gather)
                phases.append(Phase("gather", gather))
                phases.append(Phase("broadcast", bcast))
        if intra:
            phases.append(Phase("intra-bcast", intra, wan=False))
        return AggregationSchedule(kind=self.kind, root=root,
                                   phases=tuple(phases))

    def _ring_order(self, beliefs: LinkBeliefs) -> Tuple[str, ...]:
        """Cyclic region order maximizing the slowest ring link (the whole
        ring waits on it).  Brute force over cycles — region counts are
        single-digit; beyond that the given order stands."""
        regions = self.regions
        if len(regions) <= 3 or len(regions) > 8:
            # 3 regions: every cycle crosses every link — nothing to choose
            return regions
        first = regions[0]
        best: Optional[Tuple[float, Tuple[str, ...]]] = None
        for rest in itertools.permutations(sorted(regions[1:])):
            order = (first,) + rest
            bottleneck = min(
                beliefs.mbps(order[i], order[(i + 1) % len(order)])
                for i in range(len(order)))
            if best is None or (bottleneck, order) > best:
                best = (bottleneck, order)
        return best[1]

    def _route(self, leaf: str, root: str, regions: Sequence[str],
               beliefs: LinkBeliefs) -> LinkLeg:
        """Direct leg leaf->root, or the auxiliary two-hop route when the
        direct link's belief has collapsed: the relay maximizing the
        bottleneck bandwidth wins iff that bottleneck beats the direct
        belief by ``collapse_ratio`` (routing around noise would thrash;
        routing around a cliff-snap is the point)."""
        direct = beliefs.mbps(leaf, root)
        best_via, best_bn = None, 0.0
        for via in sorted(regions):
            if via in (leaf, root):
                continue
            bn = min(beliefs.mbps(leaf, via), beliefs.mbps(via, root))
            if bn > best_bn:
                best_via, best_bn = via, bn
        if best_via is not None and best_bn > self.collapse_ratio * direct:
            return LinkLeg(leaf, root, via=best_via)
        return LinkLeg(leaf, root)

    def estimate_round_s(self, payload_mb: float, beliefs: LinkBeliefs,
                         *, latency_s: float = 0.0) -> float:
        """Deterministic per-round cost at the current beliefs — what the
        planner compares candidate shapes with (no rng, no fluctuation)."""
        return self.compile(beliefs).round_s(
            payload_mb, beliefs.mbps, intra_mbps=self.intra_mbps,
            latency_s=latency_s)


# ---------------------------------------------------------------------------
# the hierarchical transport: bit-exact shipping, topology-aware billing
# ---------------------------------------------------------------------------


class HierarchicalTransport(WanTransport):
    """Hierarchical aggregation behind the PR-5 transport seam.

    Shipping delegates to the inline ring — the code path the legacy jit
    traces — so flat-ring and hierarchical runs are **bit-identical**; the
    topology lives entirely in the *billing*: each sync round costs the
    compiled schedule's phases, per-leg at that link's traced bandwidth
    through the DES transfer law.  Billed per-leg times feed the link
    beliefs (cliff-snap per link), and the schedule recompiles after every
    round — a collapse observed at round k ships over the auxiliary route
    at round k+1, one sync round after discovery (the honest price of
    measured feedback, same as PR 5's single-link probe).

    ``link_traces`` maps inter-region links (canonical
    ``link_key(a, b)`` tuples) to their own :class:`BandwidthTrace`;
    ``trace`` is the default for unmapped links.  The caller owns the
    clock (``tick``), exactly like :class:`~repro.core.transport.SimTransport`.
    """

    in_graph = True

    def __init__(self, spec: TopologySpec, trace: BandwidthTrace,
                 wan: Optional[WANConfig] = None,
                 link_traces: Optional[Mapping[Link, BandwidthTrace]] = None,
                 probe: Optional[MeasuredWanProbe] = None,
                 beliefs: Optional[LinkBeliefs] = None):
        super().__init__()
        self.spec = spec
        self.trace = trace
        self.link_traces = dict(link_traces or {})
        for key in self.link_traces:
            if link_key(*key) != key:
                raise ValueError(f"link_traces key {key} is not canonical; "
                                 f"use link_key(a, b)")
        self.wan = wan if wan is not None else WANConfig()
        self.probe = probe
        self.beliefs = (beliefs if beliefs is not None
                        else LinkBeliefs(default_mbps=trace.mbps[0]))
        self.clock_s = 0.0
        self._rng = np.random.default_rng(self.wan.seed)
        self.schedule = spec.compile(self.beliefs)
        self.reroutes: List[Tuple[Optional[int], str]] = []
        self.switches: List[Tuple[Optional[int], str, str]] = []

    # -------------------------------------------------------------- clock
    def tick(self, dt_s: float) -> None:
        self.clock_s += dt_s

    def link_mbps(self, a: str, b: str) -> float:
        """The link's *physical* bandwidth right now (its trace at the sim
        clock) — what billing draws from; beliefs only ever see billed
        transfers."""
        return self.link_traces.get(link_key(a, b), self.trace).at(
            self.clock_s)

    # ----------------------------------------------------------- actuation
    def set_kind(self, kind: str, step: Optional[int] = None) -> None:
        """Adopt a new topology shape (the planner's actuator call).  Takes
        effect at the next sync round's billing; numerics are untouched —
        shipping is the same inline ring either way."""
        if kind != self.spec.kind:
            self.switches.append((step, self.spec.kind, kind))
            self.spec = self.spec.with_kind(kind)
            self._recompile(step)

    def _recompile(self, step: Optional[int] = None) -> None:
        was_aux = self.schedule.uses_aux_route
        self.schedule = self.spec.compile(self.beliefs)
        if self.schedule.uses_aux_route and not was_aux:
            legs = [leg for leg in self.schedule.wan_legs
                    if leg.via is not None]
            self.reroutes.append(
                (step, ", ".join(f"{leg.src}->{leg.via}->{leg.dst}"
                                 for leg in legs)))

    @property
    def wan_transfers_per_round(self) -> int:
        """Traffic multiplier for the launcher/cost accounting: payload-
        sized WAN transfers per sync round under the current schedule
        (the flat ring's value is ``n_pods``)."""
        return self.schedule.wan_transfers

    # ------------------------------------------------------------ shipping
    def ship_bucket(self, name: str, chunks: Sequence[ChunkPayload],
                    shift: int, payload_mb: float = 0.0
                    ) -> Tuple[ChunkPayload, ...]:
        # traceable; billing lives in on_sync where sizes are static.
        # Delegating to the inline ring is the parity guarantee: the
        # hierarchy reshapes WHO pays for the bytes and WHEN, never the
        # bytes themselves.
        return _INLINE_RING.ship_bucket(name, chunks, shift, payload_mb)

    def on_sync(self, wire_mb: Mapping[str, float],
                step: Optional[int] = None) -> float:
        """Bill one hierarchical round at the current schedule: intra legs
        at fabric speed, each WAN hop one seeded ``transfer_time`` draw at
        its link's traced bandwidth; phases sum, legs within a phase take
        the slowest.  Every billed hop feeds that link's belief, then the
        schedule recompiles — the auxiliary-route / reorder reaction to
        what this round measured."""
        total = sum(wire_mb.values())
        if total <= 0.0:
            return 0.0
        t = self._bill_round(total)
        for name, mb in wire_mb.items():
            self.records.append(TransferRecord(
                bucket=name, payload_mb=mb, seconds=t * mb / total,
                step=step))
        if self.probe is not None:
            self.probe.observe_transfer(total, t)
        self._recompile(step)
        return t

    def _bill_round(self, total_mb: float) -> float:
        """Price one schedule traversal of ``total_mb``: intra legs at
        fabric speed, each WAN hop one seeded ``transfer_time`` draw at its
        link's traced bandwidth, every billed hop feeding that link's
        belief.  Shared by ``on_sync`` and the streaming round (which
        draws it once at ``begin_stream_round`` and, on a retune, once
        more for the re-encoded tail)."""
        t = 0.0
        for phase in self.schedule.phases:
            if not phase.legs:
                continue
            if not phase.wan:
                t += total_mb * 8.0 / self.spec.intra_mbps
                continue
            slowest = 0.0
            for leg in phase.legs:
                leg_t = 0.0
                for a, b in leg.hops:
                    hop_t = transfer_time(total_mb, self.link_mbps(a, b),
                                          self.wan, self._rng)
                    self.beliefs.observe(a, b, total_mb * 8.0 / hop_t)
                    leg_t += hop_t
                slowest = max(slowest, leg_t)
            t += slowest
        return t

    # ------------------------------------------- streaming round protocol
    supports_streaming = True

    def begin_stream_round(self, wire_mb: Mapping[str, float],
                           step: Optional[int] = None) -> bool:
        """Arm a streaming round: bill the whole schedule traversal now
        (same rng draws, same belief observations as ``on_sync`` would
        make), so a zero-retune round is bit-identical to the classic
        path.  Observing beliefs at round-open is safe: nothing consults
        them mid-round — the planner reads them at the next step's top and
        the schedule recompiles only at ``end_stream_round``."""
        total = sum(wire_mb.values())
        if total <= 0.0:
            return False
        t = self._bill_round(total)
        self._stream = _StreamRound(step, wire_mb, t)
        return True

    def stream_chunk(self, name: str, chunk_mb: float) -> float:
        secs = self._stream.bill(name, chunk_mb)
        if self.probe is not None:
            self.probe.observe_chunk(chunk_mb, secs)
        return secs

    def stream_ship_chunk(self, name: str, chunk: ChunkPayload, shift: int,
                          chunk_mb: float) -> Tuple[ChunkPayload, float]:
        shipped = _INLINE_RING.ship_bucket(name, (chunk,), shift,
                                           chunk_mb)[0]
        return shipped, self.stream_chunk(name, chunk_mb)

    def retune_stream(self, tail_mb: float) -> None:
        """Abort the unsent schedule: the re-encoded tail pays one fresh
        schedule traversal at the links' *current* traced bandwidths
        (feeding the beliefs a second round of samples — the collapsed
        link is repriced twice in one round)."""
        st = self._stream
        st.retuned = True
        st.tail_mb = float(tail_mb)
        st.t_tail = self._bill_round(tail_mb) if tail_mb > 0.0 else 0.0

    def end_stream_round(self) -> float:
        st = self._stream
        self._stream = None
        if not st.retuned:
            # canonical per-bucket split of the round traversal — NOT a
            # sum of chunk slices, so records match ``on_sync`` bit for bit
            for name, mb in st.wire_mb.items():
                self.records.append(TransferRecord(
                    bucket=name, payload_mb=mb,
                    seconds=st.t_round * mb / st.total, step=st.step))
        else:
            for name, mb in st.shipped.items():
                self.records.append(TransferRecord(
                    bucket=name, payload_mb=mb,
                    seconds=st.billed.get(name, 0.0), step=st.step))
        t = st.t_total
        mb_obs = st.total if not st.retuned else st.shipped_mb
        if self.probe is not None:
            self.probe.observe_transfer(mb_obs, t)
        self._recompile(st.step)
        self.stream_rounds.append({
            "step": st.step, "total_mb": st.total, "t_round": st.t_round,
            "chunks": list(st.chunks), "retuned": st.retuned,
            "tail_mb": st.tail_mb, "t_tail": st.t_tail,
            "shipped_mb": st.shipped_mb, "t_s": t,
        })
        return t


# ---------------------------------------------------------------------------
# the actuator head: topology as a controller-schedulable knob
# ---------------------------------------------------------------------------


class TopologyPlanner:
    """Chooses the aggregation shape from the link beliefs — the third
    actuator next to tier and interval.

    Deterministic control law (the replay gate's contract): every
    candidate shape is priced with ``TopologySpec.estimate_round_s`` at
    the shared beliefs; a challenger must beat the incumbent's estimate by
    ``switch_margin`` for ``hysteresis`` consecutive decisions before the
    switch fires (same anti-flap discipline as the codec rungs).  Wire
    ``AdaptiveSyncController(topology=planner)`` to fold decisions into
    the controller's update stream, and give ``apply`` a transport's
    ``set_kind`` so a decision actuates."""

    def __init__(self, spec: TopologySpec, beliefs: LinkBeliefs, *,
                 candidates: Sequence[str] = TOPOLOGY_KINDS,
                 hysteresis: int = 2, switch_margin: float = 0.85,
                 latency_s: float = 0.0,
                 apply: Optional[Callable[[str, Optional[int]], None]] = None):
        for kind in candidates:
            if kind not in TOPOLOGY_KINDS:
                raise ValueError(f"unknown topology candidate {kind!r}")
        if not 0.0 < switch_margin <= 1.0:
            raise ValueError("switch_margin must be in (0, 1]")
        self.spec = spec
        self.beliefs = beliefs
        self.candidates = tuple(candidates)
        self.hysteresis = hysteresis
        self.switch_margin = switch_margin
        self.latency_s = latency_s
        self.apply = apply
        self.kind = spec.kind
        self._streak = 0
        self.decisions: List[Tuple[int, str, str, str]] = []
        #   (step, from_kind, to_kind, reason)

    def estimates(self, payload_mb: float) -> Dict[str, float]:
        return {k: self.spec.with_kind(k).estimate_round_s(
                    payload_mb, self.beliefs, latency_s=self.latency_s)
                for k in self.candidates}

    def decide(self, step: int, payload_mb: float) -> Optional[str]:
        """One planner step; returns the new kind when a switch fires."""
        est = self.estimates(payload_mb)
        best = min(self.candidates, key=lambda k: (est[k], k))
        if best == self.kind or not (
                est[best] < self.switch_margin * est[self.kind]):
            self._streak = 0
            return None
        self._streak += 1
        if self._streak < self.hysteresis:
            return None
        old, self.kind, self._streak = self.kind, best, 0
        reason = (f"topo-cost:{old}->{best}"
                  f"@{est[best]:.4f}s<{est[old]:.4f}s")
        self.decisions.append((step, old, best, reason))
        if self.apply is not None:
            self.apply(best, step)
        return best
