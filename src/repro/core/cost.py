"""Monetary cost accounting (paper Fig 8 d-f).

The paper's cost waste model: resources in every cloud stay allocated for
the whole job makespan, so a cloud that finishes its local work early burns
``units × rate × waiting_time``.  Elastic scheduling trims allocations so
waiting (and hence cost) shrinks while the makespan stays put (it is set by
the straggler either way).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Mapping, Optional, Sequence

from repro.core.sync import CODEC_TIERS, SyncConfig
from repro.core.wan import SimResult


@dataclass(frozen=True)
class CostReport:
    total_cost: float
    waiting_cost: float            # cost attributable to straggler waiting
    cost_by_region: Dict[str, float]
    wait_fraction_by_region: Dict[str, float]
    traffic_mb: float = 0.0        # bytes-on-wire across all regions (WAN
    #   egress is already billed into per-region cost by the simulator when
    #   WANConfig.traffic_cost_per_gb is set; this is the volume itself)

    def reduction_vs(self, baseline: "CostReport") -> float:
        return 1.0 - self.total_cost / baseline.total_cost

    def waiting_reduction_vs(self, baseline: "CostReport") -> float:
        if baseline.waiting_cost == 0:
            return 0.0
        return 1.0 - self.waiting_cost / baseline.waiting_cost

    def traffic_reduction_vs(self, baseline: "CostReport") -> float:
        """Bytes-on-wire reduction — how the fused WAN codec shows up in the
        elasticity cost model (``SyncConfig.payload_mb`` drives both)."""
        if baseline.traffic_mb == 0:
            return 0.0
        return 1.0 - self.traffic_mb / baseline.traffic_mb


def tier_payload_table(model_mb: float, frac: float,
                       codec_block: int = 4096, interval: int = 8
                       ) -> Dict[str, Dict[str, float]]:
    """Per-sync payload for every codec tier at one (frac, block) point —
    the precision-ladder price list the adaptive controller walks and the
    ``BENCH_wan_codec.json`` bytes-on-wire rows report.

    ``fp32`` here is the sparse fp32 path (value+int32-index pairs, codec
    off); ``dense`` is the uncompressed reference.  Egress per tier is the
    per-step average at the given sync ``interval``."""
    rows: Dict[str, Dict[str, float]] = {
        "dense": {"payload_mb": model_mb,
                  "per_step_mb": model_mb / interval}}
    base = SyncConfig("asgd_ga", interval, compress_topk=frac,
                      codec_block=codec_block)
    rows["fp32"] = {"payload_mb": base.payload_mb(model_mb)}
    for dtype in CODEC_TIERS[1:]:
        cfg = replace(base, quantize_int8=True, value_dtype=dtype)
        rows[dtype] = {"payload_mb": cfg.payload_mb(model_mb)}
    for name, row in rows.items():
        row["per_step_mb"] = row["payload_mb"] / interval
        row["reduction_vs_dense"] = model_mb / row["payload_mb"]
        for k in row:
            row[k] = round(row[k], 4)
    return rows


def bucket_payload_table(cfg: SyncConfig, bucket_mb: Mapping[str, float],
                         wan_legs: Optional[int] = None
                         ) -> Dict[str, Dict[str, float]]:
    """Per-bucket traffic table for a layer-class config: each bucket
    group's model bytes, effective (top-k, tier) knobs, per-sync payload
    and reduction vs its dense share — the per-bucket price list the
    :class:`~repro.core.autotune.BucketedSyncController` walks, and what
    the bench reports next to its decisions.  A ``total`` row sums the
    groups (equals ``cfg.payload_mb(model_mb, bucket_weights=...)``).

    ``wan_legs`` — payload-sized WAN transfers per sync round under the
    live aggregation schedule (``AggregationSchedule.wan_transfers``; the
    flat ring's value is ``n_pods``) — adds a ``wire_mb`` column per row:
    what one sync round actually puts on the WAN, not just what one pod
    encodes."""
    rows: Dict[str, Dict[str, float]] = {}
    total_mb = sum(bucket_mb.values())
    total_payload = 0.0
    for name in cfg.bucket_names:
        mb = float(bucket_mb.get(name, 0.0))
        eff = cfg.for_bucket(name)
        payload = eff.payload_mb(mb)
        total_payload += payload
        rows[name] = {
            "model_mb": round(mb, 4),
            "compress_topk": eff.compress_topk,
            "tier": CODEC_TIERS[eff.tier],
            # the per-bucket block override changes the wire bytes (one
            # fp32 scale per block — the 1/block payload term), so the
            # price list shows it next to the payload it produced
            "codec_block": eff.codec_block,
            "payload_mb": round(payload, 6),
            "reduction_vs_dense": round(mb / payload, 2) if payload else 0.0,
        }
    rows["total"] = {
        "model_mb": round(total_mb, 4),
        "payload_mb": round(total_payload, 6),
        "reduction_vs_dense": (round(total_mb / total_payload, 2)
                               if total_payload else 0.0),
    }
    if wan_legs is not None:
        for row in rows.values():
            row["wire_mb"] = round(row["payload_mb"] * wan_legs, 6)
    return rows


def adaptive_traffic_mb(decisions: Sequence, n_syncs_per_decision: Sequence[int],
                        model_mb: float, n_pods: int = 1,
                        bucket_weights: Optional[Mapping[str, float]] = None,
                        wan_legs: Optional[int] = None) -> float:
    """Bytes-on-wire of an adaptive run: each controller decision's config
    billed for the sync rounds it was live (``SyncPlanUpdate.sync`` carries
    the payload math; the launcher's traffic accounting uses the same
    ``payload_mb`` per active config, so simulator and emulation agree).
    Pass ``bucket_weights`` for a multi-bucket decision stream — each
    decision's per-bucket overrides are then billed at their own tier.

    The per-round multiplier is the number of payload-sized WAN transfers
    one sync round makes.  The historical default, ``n_pods``, is exact
    for the flat ring only (every pod ships to one peer).  Under a
    hierarchical schedule pass ``wan_legs``
    (``AggregationSchedule.wan_transfers`` / the transport's
    ``wan_transfers_per_round``): a tree over R regions makes ``2(R-1)``
    transfers, not ``n_pods``, and auxiliary routes pay two hops — the
    same count the DES bills (exact-accounting-tested against
    ``wan.simulate``)."""
    legs = wan_legs if wan_legs is not None else n_pods
    total = 0.0
    for update, n in zip(decisions, n_syncs_per_decision):
        total += update.sync.payload_mb(
            model_mb, bucket_weights=bucket_weights) * n * legs
    return total


def cost_report(result: SimResult, units: Dict[str, int],
                rates: Dict[str, float]) -> CostReport:
    by_region, wait_frac, waiting = {}, {}, 0.0
    for c in result.clouds:
        by_region[c.region] = c.cost
        wait_frac[c.region] = c.wait_fraction
        waiting += units[c.region] * rates[c.region] * c.wait_s / 3600.0
    return CostReport(
        total_cost=result.total_cost,
        waiting_cost=waiting,
        cost_by_region=by_region,
        wait_fraction_by_region=wait_frac,
        traffic_mb=result.total_traffic_mb,
    )
