"""Monetary cost accounting (paper Fig 8 d-f).

The paper's cost waste model: resources in every cloud stay allocated for
the whole job makespan, so a cloud that finishes its local work early burns
``units × rate × waiting_time``.  Elastic scheduling trims allocations so
waiting (and hence cost) shrinks while the makespan stays put (it is set by
the straggler either way).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

from repro.core.wan import SimResult


@dataclass(frozen=True)
class CostReport:
    total_cost: float
    waiting_cost: float            # cost attributable to straggler waiting
    cost_by_region: Dict[str, float]
    wait_fraction_by_region: Dict[str, float]
    traffic_mb: float = 0.0        # bytes-on-wire across all regions (WAN
    #   egress is already billed into per-region cost by the simulator when
    #   WANConfig.traffic_cost_per_gb is set; this is the volume itself)

    def reduction_vs(self, baseline: "CostReport") -> float:
        return 1.0 - self.total_cost / baseline.total_cost

    def waiting_reduction_vs(self, baseline: "CostReport") -> float:
        if baseline.waiting_cost == 0:
            return 0.0
        return 1.0 - self.waiting_cost / baseline.waiting_cost

    def traffic_reduction_vs(self, baseline: "CostReport") -> float:
        """Bytes-on-wire reduction — how the fused WAN codec shows up in the
        elasticity cost model (``SyncConfig.payload_mb`` drives both)."""
        if baseline.traffic_mb == 0:
            return 0.0
        return 1.0 - self.traffic_mb / baseline.traffic_mb


def cost_report(result: SimResult, units: Dict[str, int],
                rates: Dict[str, float]) -> CostReport:
    by_region, wait_frac, waiting = {}, {}, 0.0
    for c in result.clouds:
        by_region[c.region] = c.cost
        wait_frac[c.region] = c.wait_fraction
        waiting += units[c.region] * rates[c.region] * c.wait_s / 3600.0
    return CostReport(
        total_cost=result.total_cost,
        waiting_cost=waiting,
        cost_by_region=by_region,
        wait_fraction_by_region=wait_frac,
        traffic_mb=result.total_traffic_mb,
    )
