"""Inter-pod model-synchronization strategies (paper §III.C) on SPMD/TPU.

Representation: every training-state leaf carries a leading ``pod`` dimension
of size ``n_pods`` (the number of cloud partitions), sharded over the
``"pod"`` mesh axis.  The per-pod train step is ``jax.vmap``-ed over that
dimension, and the paper's WAN synchronization primitives become array ops on
it, which XLA SPMD lowers to exactly the right collectives:

- ``jnp.roll(x, shift, axis=0)``  -> ``collective-permute`` over ``"pod"`` —
  the TPU analogue of the paper's one-PS-to-one-peer gRPC send (the paper:
  "Cloudless-Training limits each PS to send its state to only one other PS
  each time").
- ``jnp.mean(x, axis=0)``         -> ``all-reduce`` over ``"pod"`` — the
  barrier average of SMA (and the per-step reduction of the ASGD baseline).

Strategies (paper §III.C):

- **ASGD (baseline)** — "simple asynchronous SGD", sync frequency 1: the
  gradient is averaged across pods *every* step.
- **ASGD-GA** — gradients are accumulated locally for ``interval`` steps; at
  a sync point each pod ships the *accumulated* gradient to one ring peer and
  applies the received gradient as an extra SGD update (receiver-side SGD per
  the paper).  Between syncs pods run fully independently; under SPMD the
  asynchrony becomes a bounded one-round staleness window.
- **AMA** — inter-PS model averaging, asynchronous pattern: every
  ``interval`` steps each pod averages parameters with one ring peer
  (gossip averaging; pairwise == global for the paper's 2-cloud setup).
- **SMA** — synchronous pattern: global barrier average over all pods
  (paper Fig 11: best accuracy, highest sync cost).

Beyond-paper option: ``compress_topk`` ships only the top-k fraction of
accumulated-gradient entries (the paper cites DGC/top-K as the complementary
WAN-optimization family but does not implement it); see
``repro.kernels.topk_compress``.  It compounds with ASGD-GA's frequency
reduction to cut inter-pod bytes further.

With ``quantize_int8`` the top-k path upgrades to the **fused WAN codec**
(``repro.kernels.wan_codec``), the full payload pipeline:

  bucket -> top-k -> int8 -> ring -> decode -> error feedback

- **bucket**: the accumulated-gradient pytree is packed once into a single
  contiguous ``(n_pods, N)`` buffer, so compression is a handful of fused
  dispatches instead of one per leaf.  Under ``bucket_policy=
  "layer-class"`` the buffer is *grouped by layer class* (embed / norm /
  dense / MoE — :class:`BucketSpec` classifies leaves by parameter path),
  each group a contiguous segment with its OWN ``(compress_topk,
  value_dtype)`` knobs and EF telemetry: aggressive compression where the
  gradient statistics make it free, conservative where it hurts.
- **top-k + int8**: a single-pass Pallas kernel selects the block-local
  top-k and quantizes the winners to int8 with per-block scales — payload
  bytes drop to ``~0.75 * compress_topk`` of dense fp32 (int8 value + u16
  local index per kept element, vs the fp32+int32 pairs of the unquantized
  path); see ``SyncConfig.payload_mb``.
- **ring**: the *compact* (q, idx, scales) triple is what rolls over the
  pod axis (collective-permute) — never the dense buffer.  With
  ``overlap_chunks > 1`` the bucket is split on codec-block boundaries and
  the permute of chunk i is data-independent of the encode of chunk i+1,
  so the WAN transfer hides behind the remaining compression work (TAAR's
  overlap, arXiv:2404.11352); chunking is bit-exact vs the unchunked path.
- **error feedback** (``error_feedback=True``): each pod keeps the residual
  ``message - decode(encode(message))`` — everything top-k dropped plus the
  quantization rounding — and re-injects it into the next interval's
  message (EF-SGD semantics), so aggressive compression stops costing
  convergence instead of silently discarding gradient mass.

Because the representation is pure ``jnp`` on a stacked dimension, the same
code runs (a) multi-pod on TPU via sharding, and (b) as a faithful multi-cloud
*emulation* on a single CPU device — which is how the convergence-parity
tests reproduce the paper's Figs 7/9/10 accuracy results for real.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace as _dc_replace
from typing import (Any, Dict, Mapping, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np
from math import prod as np_prod

Pytree = Any

STRATEGIES = ("asgd", "asgd_ga", "ama", "sma", "asp")

# the codec's precision ladder, least -> most aggressive.  Tier 0 (fp32) is
# "codec off": sparse fp32 (value+index pairs) or fully dense.  Wire bytes
# per kept element: fp32 4+4 (int32 index), int8/fp8 1+2 (u16 block-local
# index), int4 0.5+2 — plus one fp32 scale per codec block on tiers >= 1.
CODEC_TIERS = ("fp32", "int8", "fp8", "int4")
VALUE_DTYPES = CODEC_TIERS[1:]
_VALUE_BYTES = {"int8": 1.0, "fp8": 1.0, "int4": 0.5}


# ---------------------------------------------------------------------------
# bucket groups: layer-class partitioning of the sync payload
# ---------------------------------------------------------------------------
#
# Gradient statistics are wildly non-uniform across layer classes: embedding
# rows are touched sparsely (top-k is nearly free), norms/biases are tiny but
# convergence-critical (compression buys nothing and hurts), MoE expert
# blocks see token-routed sparsity, and the attention/MLP dense bulk is where
# the bytes actually live.  The layer-class bucket policy splits the one flat
# codec bucket into named groups so each can run its own (top-k x dtype)
# aggression — the per-tensor adaptation network-aware geo-distributed
# systems converge on (TAAR, arXiv:2404.11352; HeterPS, arXiv:2111.10635).

BUCKET_CLASSES = ("embed", "norm", "dense", "moe")
BUCKET_POLICIES = ("single", "layer-class")


@dataclass(frozen=True)
class BucketSpec:
    """Classifies pytree leaves into named bucket groups.

    A leaf's parameter *path* (``jax.tree_util.keystr``) is matched against
    per-group substring patterns, first hit wins (``patterns`` order is the
    precedence order — MoE before embed so ``moe/router`` lands in the
    expert group).  Pattern-less leaves fall through on shape: rank <= 1
    per-pod tensors (biases, norm scales, per-feature vectors) go to
    ``vector_bucket``, everything else to ``fallback``.  The default
    patterns are the same path vocabulary ``sharding/rules.py`` keys its
    logical axes on (vocab/embed, experts/router, heads/d_ff dense).

    The table is user-definable: a ``SyncConfig`` carries its spec
    (``bucket_spec``), the launcher parses one from ``--bucket-patterns``
    (:meth:`parse`), and every downstream consumer — layout, validation,
    per-bucket knobs, the adaptive controllers — follows the spec's
    ``names``.  The spec is frozen/hashable so it rides inside the
    jit-static ``SyncConfig`` without disturbing the compiled-sync cache."""

    names: Tuple[str, ...] = BUCKET_CLASSES
    patterns: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("moe", ("moe", "expert", "router")),
        ("embed", ("embed", "emb", "vocab", "wte", "wpe", "lm_head",
                   "tok_", "token")),
        ("norm", ("norm", "ln1", "ln2", "rms", "bias", "scale")),
    )
    vector_bucket: str = "norm"
    fallback: str = "dense"

    def __post_init__(self):
        if not self.names or len(set(self.names)) != len(self.names):
            raise ValueError("bucket spec needs non-empty, unique names, "
                             f"got {self.names}")
        for name, subs in self.patterns:
            if name not in self.names:
                raise ValueError(
                    f"bucket spec pattern group {name!r} is not one of its "
                    f"names {self.names}")
            if not subs:
                raise ValueError(f"bucket spec group {name!r} has an empty "
                                 f"pattern list")
        for role, name in (("vector_bucket", self.vector_bucket),
                           ("fallback", self.fallback)):
            if name not in self.names:
                raise ValueError(
                    f"bucket spec {role} {name!r} is not one of its names "
                    f"{self.names}")

    def classify(self, path: str, inner_ndim: int) -> str:
        """Bucket name for one leaf (``inner_ndim`` excludes the pod dim)."""
        low = path.lower()
        for name, subs in self.patterns:
            if any(s in low for s in subs):
                return name
        return self.vector_bucket if inner_ndim <= 1 else self.fallback

    @classmethod
    def parse(cls, spec: str) -> "BucketSpec":
        """Build a spec from the launcher's ``--bucket-patterns`` string.

        Named presets: ``default`` (the four-class table) and
        ``moe-router`` (:data:`MOE_ROUTER_BUCKET_SPEC` — routers split out
        of the expert group).  Otherwise, semicolon-separated
        ``name=sub1|sub2`` pattern groups in precedence order, plus the
        optional directives ``vector=name`` / ``fallback=name`` (defaults:
        ``norm`` / ``dense`` if those names exist, else the last group /
        the first pattern-less group)::

            router=router;moe=moe|expert;embed=embed|vocab;norm=norm|bias;dense=

        Groups may be declared pattern-less (``dense=``) just to exist as
        a fallback target."""
        key = spec.strip().lower()
        if key in ("", "default"):
            return DEFAULT_BUCKET_SPEC
        if key == "moe-router":
            return MOE_ROUTER_BUCKET_SPEC
        names: list = []
        patterns: list = []
        vector = fallback = None
        for entry in spec.split(";"):
            entry = entry.strip()
            if not entry:
                continue
            name, eq, subs = entry.partition("=")
            name = name.strip()
            if not eq:
                raise ValueError(
                    f"--bucket-patterns entry {entry!r} is not "
                    f"'name=sub1|sub2' (or 'vector=name'/'fallback=name')")
            if name == "vector":
                vector = subs.strip()
                continue
            if name == "fallback":
                fallback = subs.strip()
                continue
            if name not in names:
                names.append(name)
            pats = tuple(s.strip().lower() for s in subs.split("|")
                         if s.strip())
            if pats:
                patterns.append((name, pats))
        if not names:
            raise ValueError(f"--bucket-patterns {spec!r} defines no bucket "
                             f"groups")
        for role, target in (("vector", vector), ("fallback", fallback)):
            if target is not None and target not in names:
                # refusing (not creating) catches a typoed group name —
                # a phantom group would silently swallow every fallthrough
                # leaf while the declared group stays empty
                raise ValueError(
                    f"--bucket-patterns {role}={target!r} names an "
                    f"undeclared bucket group (declared: {tuple(names)}); "
                    f"declare it, e.g. '{target}='")
        vector = vector or ("norm" if "norm" in names else names[-1])
        # fallback default: 'dense' if declared, else the first
        # pattern-LESS group (declaring 'name=' with no patterns is the
        # documented way to create a catch-all), else the last group —
        # NEVER the first: groups are listed most-specific-first, and a
        # fallback into the most specific group would silently give every
        # unmatched dense matrix e.g. router-grade treatment
        if fallback is None:
            pattern_names = {n for n, _ in patterns}
            patternless = [n for n in names if n not in pattern_names]
            fallback = ("dense" if "dense" in names
                        else (patternless[0] if patternless else names[-1]))
        return cls(names=tuple(names), patterns=tuple(patterns),
                   vector_bucket=vector, fallback=fallback)


DEFAULT_BUCKET_SPEC = BucketSpec()

# the MoE recipe's spec: routers in their OWN group instead of riding the
# expert group.  Router gradients are dense and convergence-critical (they
# steer token routing; quantization error there mis-routes tokens), while
# expert blocks see token-routed sparsity that tolerates aggressive top-k —
# one (top-k, dtype) rung cannot serve both, which is why this table exists.
# Precedence: router patterns FIRST, so ``moe/router`` no longer falls to
# the ``moe`` group's broader patterns.
MOE_ROUTER_BUCKET_SPEC = BucketSpec(
    names=("embed", "norm", "dense", "moe", "router"),
    patterns=(
        ("router", ("router", "gating")),
        ("moe", ("moe", "expert")),
        ("embed", ("embed", "emb", "vocab", "wte", "wpe", "lm_head",
                   "tok_", "token")),
        ("norm", ("norm", "ln1", "ln2", "rms", "bias", "scale")),
    ))


@dataclass(frozen=True)
class BucketLayout:
    """Concrete partition of one stacked pytree into bucket groups.

    The grouped flat buffer concatenates leaves in ``order`` (stable: by
    bucket, then original ``jax.tree.leaves`` position), so every bucket
    group owns one contiguous ``(n_pods, N_g)`` segment —
    ``[offsets[g] : offsets[g] + sizes[g])`` — of the same ``(n_pods, N)``
    buffer the EF residual lives in.  For the ``"single"`` policy the order
    is the identity and the layout degenerates to the legacy one-bucket
    packing."""

    names: Tuple[str, ...]          # bucket group names, fixed order
    leaf_bucket: Tuple[int, ...]    # bucket index per leaf (original order)
    leaf_sizes: Tuple[int, ...]     # per-leaf flat width (per pod)
    order: Tuple[int, ...]          # leaf indices in packing order
    sizes: Tuple[int, ...]          # per-bucket segment width N_g
    offsets: Tuple[int, ...]        # per-bucket segment start

    @property
    def leaf_offsets(self) -> Tuple[int, ...]:
        """Offset of each (original-index) leaf in the grouped buffer."""
        off, out = 0, [0] * len(self.order)
        for i in self.order:
            out[i] = off
            off += self.leaf_sizes[i]
        return tuple(out)

    def segment(self, name: str) -> Tuple[int, int]:
        g = self.names.index(name)
        return self.offsets[g], self.sizes[g]


def bucket_layout(cfg: "SyncConfig", stacked_tree: Pytree,
                  spec: Optional[BucketSpec] = None) -> BucketLayout:
    """Partition ``stacked_tree`` (leading pod dim) per ``cfg.bucket_policy``.

    The pattern table defaults to the config's own ``bucket_spec`` (which
    the launcher's ``--bucket-patterns`` sets).  Host-side and shape-only:
    safe to call while tracing (it runs once per compile inside the jitted
    sync step)."""
    spec = spec if spec is not None else cfg.bucket_spec
    flat, _ = jax.tree_util.tree_flatten_with_path(stacked_tree)
    leaf_sizes = tuple(int(np_prod(x.shape[1:])) for _, x in flat)
    if cfg.bucket_policy == "single":
        names = ("all",)
        leaf_bucket = (0,) * len(flat)
        order = tuple(range(len(flat)))
    else:
        names = spec.names
        leaf_bucket = tuple(
            names.index(spec.classify(jax.tree_util.keystr(path),
                                      x.ndim - 1))
            for path, x in flat)
        order = tuple(sorted(range(len(flat)),
                             key=lambda i: (leaf_bucket[i], i)))
    sizes = tuple(sum(leaf_sizes[i] for i in range(len(flat))
                      if leaf_bucket[i] == g) for g in range(len(names)))
    offsets = tuple(sum(sizes[:g]) for g in range(len(names)))
    return BucketLayout(names=names, leaf_bucket=leaf_bucket,
                        leaf_sizes=leaf_sizes, order=order,
                        sizes=sizes, offsets=offsets)


def bucket_weights_of(cfg: "SyncConfig", stacked_tree: Pytree,
                      spec: Optional[BucketSpec] = None
                      ) -> Dict[str, float]:
    """Fraction of model elements per bucket group (sums to 1.0) — the
    weights :meth:`SyncConfig.payload_mb` uses for per-bucket accounting."""
    layout = bucket_layout(cfg, stacked_tree, spec)
    total = max(1, sum(layout.sizes))
    return {n: layout.sizes[g] / total for g, n in enumerate(layout.names)}


@dataclass(frozen=True)
class BucketOverride:
    """Per-bucket codec knobs; ``None`` inherits the global SyncConfig
    value.  Carried in ``SyncConfig.buckets`` (hashable, jit-static).

    ``codec_block`` tunes the block-local top-k granularity per bucket:
    embedding-class gradients are token-sparse (their mass clusters, so a
    *small* block keeps selection local and the per-block scale tight)
    while the dense bulk amortizes better under large blocks (fewer fp32
    scales on the wire — the ``1/block`` payload term)."""

    name: str
    compress_topk: Optional[float] = None
    value_dtype: Optional[str] = None
    codec_block: Optional[int] = None


@dataclass(frozen=True)
class SyncConfig:
    strategy: str = "asgd"
    interval: int = 1              # K — sync every K steps (1 for baseline)
    peer_shift: int = 1            # ring shift for the one-peer send; must be
    #   coprime with n_pods or the gossip ring decomposes into disjoint
    #   subrings that never reach consensus (property-tested)
    compress_topk: float = 0.0     # 0/1 = dense; else fraction of entries shipped
    ga_lr_scale: float = 1.0       # LR scale for the receiver-side SGD update
    asp_threshold: float = 0.01    # ASP: relative-significance threshold
    quantize_int8: bool = False    # fused WAN codec on (value_dtype picks the
    #   payload tier; the flag name is historical — the first tier was int8)
    value_dtype: str = "int8"      # codec payload tier: int8 | fp8 | int4
    error_feedback: bool = False   # EF-SGD: re-inject compression residual
    codec_block: int = 4096        # block-local top-k block size (codec path)
    overlap_chunks: int = 1        # >1: pipeline ring permute with encode
    bucket_policy: str = "single"  # "single": one flat codec bucket (legacy);
    #   "layer-class": partition the payload into BUCKET_CLASSES groups, each
    #   with its own (top-k, dtype) knobs and EF telemetry
    buckets: Tuple[BucketOverride, ...] = ()   # per-bucket knob overrides
    #   (layer-class only); unnamed buckets inherit the global knobs
    bucket_spec: BucketSpec = DEFAULT_BUCKET_SPEC   # the layer-class
    #   pattern table (user-definable via --bucket-patterns); frozen and
    #   hashable, so it is part of the jit-static config like every other
    #   codec knob

    def __post_init__(self):
        self._validate()

    def _validate(self) -> None:
        """Each knob gets its own precise error: a run configured with a
        silently-inert flag would train one way while its summary claims
        another, so every coupling is refused with the exact reason."""
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.interval < 1:
            raise ValueError("interval must be >= 1")
        if self.overlap_chunks < 1:
            raise ValueError("overlap_chunks must be >= 1")
        if self.codec_block < 128 or self.codec_block > (1 << 16):
            raise ValueError("codec_block must be in [128, 65536] (local "
                             "indices ship as u16)")
        if self.value_dtype not in VALUE_DTYPES:
            raise ValueError(
                f"unknown value_dtype {self.value_dtype!r}: the codec's "
                f"payload tiers are {VALUE_DTYPES} (fp32 is codec-off)")
        if self.value_dtype != "int8" and not self.quantize_int8:
            raise ValueError(
                f"value_dtype={self.value_dtype!r} is inert without the "
                f"fused codec (quantize_int8=True): the run would ship "
                f"sparse/dense fp32 while its summary claims "
                f"{self.value_dtype}")
        if self.quantize_int8:
            if self.strategy != "asgd_ga":
                raise ValueError(
                    f"the fused codec (quantize_int8=True) compresses "
                    f"shipped accumulated gradients and therefore requires "
                    f"strategy='asgd_ga', not {self.strategy!r}")
            if not 0.0 < self.compress_topk < 1.0:
                raise ValueError(
                    f"the fused codec (quantize_int8=True) needs a top-k "
                    f"fraction 0 < compress_topk < 1, got "
                    f"{self.compress_topk} — without one the run would "
                    f"train dense while its summary claims "
                    f"{self.value_dtype}/EF")
        if self.error_feedback and not self.quantize_int8:
            raise ValueError("error_feedback requires the fused codec "
                             "(quantize_int8=True): the EF residual is "
                             "defined as what encode->decode lost")
        if self.overlap_chunks > 1 and not self.uses_codec:
            raise ValueError(
                "overlap_chunks > 1 requires the fused codec "
                "(strategy='asgd_ga', 0 < compress_topk < 1, "
                "quantize_int8=True): chunk pipelining only exists on the "
                "codec path")
        self._validate_buckets()

    def _validate_buckets(self) -> None:
        """Multi-bucket coupling checks.  Every message names the offending
        bucket group: a multi-bucket config has one line per group and a
        bare per-knob error would not say WHICH group is misconfigured."""
        if self.bucket_policy not in BUCKET_POLICIES:
            raise ValueError(
                f"unknown bucket_policy {self.bucket_policy!r}: choices are "
                f"{BUCKET_POLICIES}")
        if self.bucket_policy != "single" and not self.uses_codec:
            raise ValueError(
                "bucket_policy='layer-class' is inert without the fused "
                "codec (strategy='asgd_ga', 0 < compress_topk < 1, "
                "quantize_int8=True): only the codec path packs per-bucket "
                "payloads, so the run would train single-bucket while its "
                "summary claims per-bucket control")
        if not self.buckets:
            return
        if self.bucket_policy == "single":
            raise ValueError(
                f"bucket overrides ({', '.join(o.name for o in self.buckets)}"
                f") require bucket_policy='layer-class': under 'single' "
                f"there is one unnamed bucket and the overrides would be "
                f"silently ignored")
        seen = set()
        for ov in self.buckets:
            where = f"bucket {ov.name!r}: "
            if ov.name not in self.bucket_spec.names:
                raise ValueError(
                    where + f"unknown bucket group; the layer-class groups "
                    f"are {self.bucket_spec.names}")
            if ov.name in seen:
                raise ValueError(where + "duplicate override — each bucket "
                                         "group may be overridden once")
            seen.add(ov.name)
            if ov.compress_topk is not None and \
                    not 0.0 < ov.compress_topk < 1.0:
                raise ValueError(
                    where + f"compress_topk must be in (0, 1), got "
                    f"{ov.compress_topk} — a dense per-bucket payload has "
                    f"no codec selection to quantize")
            if ov.value_dtype is not None and \
                    ov.value_dtype not in VALUE_DTYPES:
                raise ValueError(
                    where + f"unknown value_dtype {ov.value_dtype!r}: the "
                    f"codec's payload tiers are {VALUE_DTYPES}")
            if ov.codec_block is not None and \
                    not 128 <= ov.codec_block <= (1 << 16):
                raise ValueError(
                    where + f"codec_block must be in [128, 65536] (local "
                    f"indices ship as u16), got {ov.codec_block}")

    # ------------------------------------------------------ bucket groups
    @property
    def bucket_names(self) -> Tuple[str, ...]:
        """Bucket group names in segment order (one unnamed group when the
        policy is ``"single"``)."""
        return (("all",) if self.bucket_policy == "single"
                else self.bucket_spec.names)

    def bucket_knobs(self, name: str) -> Tuple[float, str, int]:
        """Effective (compress_topk, value_dtype, codec_block) for one
        bucket group."""
        for ov in self.buckets:
            if ov.name == name:
                return (ov.compress_topk if ov.compress_topk is not None
                        else self.compress_topk,
                        ov.value_dtype if ov.value_dtype is not None
                        else self.value_dtype,
                        ov.codec_block if ov.codec_block is not None
                        else self.codec_block)
        return self.compress_topk, self.value_dtype, self.codec_block

    def for_bucket(self, name: str) -> "SyncConfig":
        """The effective single-bucket config governing one group's segment
        — what the codec dispatch and the payload math run with."""
        frac, dtype, block = self.bucket_knobs(name)
        return _dc_replace(self, compress_topk=frac, value_dtype=dtype,
                           codec_block=block, bucket_policy="single",
                           buckets=())

    @property
    def bucket_tiers(self) -> Tuple[int, ...]:
        """Per-bucket index into :data:`CODEC_TIERS` (segment order)."""
        return tuple(self.for_bucket(n).tier for n in self.bucket_names)

    @property
    def sends_gradients(self) -> bool:
        return self.strategy in ("asgd", "asgd_ga")

    @property
    def uses_codec(self) -> bool:
        """True when sync rounds run the fused bucket->top-k->quantize codec."""
        return (self.strategy == "asgd_ga" and self.quantize_int8
                and 0.0 < self.compress_topk < 1.0)

    @property
    def tier(self) -> int:
        """Index into :data:`CODEC_TIERS` (0 = fp32 / codec off)."""
        return CODEC_TIERS.index(self.value_dtype) if self.uses_codec else 0

    def payload_mb(self, model_mb: float,
                   measured_frac: Optional[float] = None,
                   bucket_weights: Optional[Mapping[str, float]] = None
                   ) -> float:
        """Per-sync WAN payload per pod (drives the simulator & roofline).

        Sparse fp32 ships (fp32 value, int32 index) pairs: ``2 * frac`` of
        dense.  The fused codec ships (value, u16 block-local index) pairs
        plus one fp32 scale per ``codec_block`` elements; value bytes per
        tier: int8/fp8 1, int4 0.5 (two nibble-packed codes per byte).  So
        int8/fp8 cost ``0.75 * frac + 1/codec_block`` of dense and int4
        ``0.625 * frac + 1/codec_block`` — >=8x below dense fp32 whenever
        ``frac <= 0.166`` (int8, default block) / ``frac <= 0.2`` (int4).
        For ASP pass the measured significant fraction (runtime-dependent);
        a nominal 30% is assumed otherwise (Gaia reports 10-50%).

        With ``bucket_weights`` (fraction of model elements per bucket,
        from :func:`bucket_weights_of`) a layer-class config is billed
        per bucket: each group's segment pays its *own* (top-k, dtype)
        rate.  Without weights the global knobs price the whole model —
        exact for "single", an approximation for an overridden
        layer-class config (callers that know the partition pass
        weights)."""
        if (bucket_weights is not None and self.uses_codec
                and self.bucket_policy != "single"):
            return sum(
                self.for_bucket(n).payload_mb(
                    model_mb * bucket_weights.get(n, 0.0))
                for n in self.bucket_names)
        if self.strategy == "asp":
            frac = measured_frac if measured_frac is not None else 0.3
            return model_mb * (2 * frac if frac < 1.0 else 1.0)
        if 0.0 < self.compress_topk < 1.0 and self.strategy == "asgd_ga":
            frac = self.compress_topk
            if self.quantize_int8:
                per_elem = (_VALUE_BYTES[self.value_dtype] + 2.0) / 4.0
                return model_mb * (frac * per_elem + 1.0 / self.codec_block)
            return model_mb * 2 * frac
        return model_mb


class SyncState(NamedTuple):
    ga_buffer: Pytree              # accumulated grads (ASGD-GA) or the
    #   reference params at the last sync (ASP), leading pod dim
    steps_since_sync: jnp.ndarray  # scalar int32
    significant_frac: jnp.ndarray  # ASP: fraction shipped at the last sync
    ef_residual: jnp.ndarray
    #   error-feedback residual, flat (n_pods, N) in *bucket-grouped* leaf
    #   order (what the codec dropped + quantization error, re-injected next
    #   sync); each bucket group owns one contiguous (n_pods, N_g) segment
    #   of it (see BucketLayout); (n_pods, 0) when the codec/EF path is off.
    #   Deliberately no default: a defaulted jnp array would be built at
    #   import time AND let stale 3-field constructor calls silently produce
    #   a wrong pod dim — ``init_sync_state`` is the way to build one
    tier: jnp.ndarray              # (n_buckets,) int32 indices into
    #   CODEC_TIERS — each bucket group's payload tier at the last sync
    #   (survives retunes/resizes, so logs and checkpoints can tell what
    #   the adaptive controller chose per bucket; length 1 under "single")
    msg_norm: jnp.ndarray          # (n_pods, n_buckets) L2 of the last
    #   codec sync's pre-compression message per bucket segment
    #   (accumulated grad avg + EF residual)
    resid_norm: jnp.ndarray        # (n_pods, n_buckets) L2 of the
    #   post-sync EF residual per bucket segment.  msg/resid norms are the
    #   adaptive controllers' per-bucket gradient-statistics inputs; zeros
    #   off the codec path


def init_sync_state(cfg: SyncConfig, stacked_params: Pytree) -> SyncState:
    """``stacked_params`` leaves have the leading pod dimension."""
    n_pods = jax.tree.leaves(stacked_params)[0].shape[0]
    if cfg.strategy == "asgd_ga":
        buf = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), stacked_params)
    elif cfg.strategy == "asp":
        buf = jax.tree.map(
            lambda p: p.astype(jnp.float32), stacked_params)
    else:
        buf = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32),
                           stacked_params)
    n_ef = (sum(x.size for x in jax.tree.leaves(stacked_params)) // n_pods
            if (cfg.uses_codec and cfg.error_feedback) else 0)
    nb = len(cfg.bucket_names)
    return SyncState(ga_buffer=buf,
                     steps_since_sync=jnp.zeros((), jnp.int32),
                     significant_frac=jnp.ones((), jnp.float32),
                     ef_residual=jnp.zeros((n_pods, n_ef), jnp.float32),
                     tier=jnp.asarray(cfg.bucket_tiers, jnp.int32),
                     msg_norm=jnp.zeros((n_pods, nb), jnp.float32),
                     resid_norm=jnp.zeros((n_pods, nb), jnp.float32))


# ---------------------------------------------------------------------------
# per-step hook (inside the jitted train step)
# ---------------------------------------------------------------------------


def on_step_gradients(cfg: SyncConfig, grads: Pytree, state: SyncState
                      ) -> Tuple[Pytree, SyncState]:
    """Process fresh per-pod gradients (leading pod dim, already averaged over
    the intra-pod data axis by the loss mean).  Returns (gradients for the
    local optimizer update, new sync state)."""
    n_pods = jax.tree.leaves(grads)[0].shape[0]
    bump = state._replace(steps_since_sync=state.steps_since_sync + 1)

    if cfg.strategy == "asgd" and n_pods > 1:
        # baseline: cross-pod all-reduce every step
        grads = jax.tree.map(
            lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True),
                                       g.shape),
            grads)
        return grads, bump

    if cfg.strategy == "asgd_ga":
        buf = jax.tree.map(lambda b, g: b + g.astype(jnp.float32),
                           state.ga_buffer, grads)
        return grads, bump._replace(ga_buffer=buf)

    return grads, bump


# ---------------------------------------------------------------------------
# sync point (a separate jitted function, invoked every K host steps)
# ---------------------------------------------------------------------------


# --------------------------------------------------- bucketed WAN codec path


def _pack_stacked(tree: Pytree,
                  layout: Optional[BucketLayout] = None) -> jnp.ndarray:
    """Pack a stacked pytree into one contiguous (n_pods, N) bucket buffer.

    One concatenate amortizes the per-leaf compression dispatch the legacy
    path pays.  Without a layout, leaf order (jax.tree.leaves) defines the
    buffer order; with one, leaves are grouped by bucket (``layout.order``)
    so each bucket group is a contiguous segment — either way the result's
    order is the order ``ef_residual`` is stored in."""
    leaves = jax.tree.leaves(tree)
    if layout is not None:
        leaves = [leaves[i] for i in layout.order]
    return jnp.concatenate(
        [x.reshape(x.shape[0], -1).astype(jnp.float32) for x in leaves],
        axis=1)


def _unpack_stacked(flat: jnp.ndarray, like: Pytree,
                    layout: Optional[BucketLayout] = None) -> Pytree:
    """Inverse of :func:`_pack_stacked` against a reference pytree."""
    leaves, treedef = jax.tree.flatten(like)
    offsets = (layout.leaf_offsets if layout is not None else None)
    out, off = [], 0
    for i, x in enumerate(leaves):
        size = int(np_prod(x.shape[1:]))
        lo = offsets[i] if offsets is not None else off
        out.append(flat[:, lo:lo + size].reshape(x.shape))
        off += size
    return jax.tree.unflatten(treedef, out)


class ChunkPayload(NamedTuple):
    """One overlap chunk's compact wire triple — exactly what crosses the
    pod axis: quantized values (tier dtype; int4 already nibble-packed),
    u16 block-local indices, and per-block fp32 scales."""

    q: jnp.ndarray
    idx: jnp.ndarray       # uint16 on the wire (block-local, < 65536)
    scales: jnp.ndarray


class SyncPayloads(NamedTuple):
    """Output of the codec's *decide/pack* stage (jit-transparent pytree):
    the dense pre-compression message, its local reconstruction (what this
    pod's peer will decode — needed for the EF residual), and the
    per-bucket wire chunks a :class:`~repro.core.transport.WanTransport`
    ships.  Empty bucket groups are absent from ``chunks``."""

    flat: jnp.ndarray                               # (n_pods, N) message
    local: Optional[jnp.ndarray]                    # decode-at-sender (EF)
    chunks: Dict[str, Tuple[ChunkPayload, ...]]     # non-empty buckets


def _chunk_widths(cfg: SyncConfig, n_total: int) -> Tuple[int, ...]:
    """Static per-chunk dense widths of one bucket segment.

    Chunks split on codec-block boundaries, so the chunked selection is
    bit-identical to the unchunked one; host-side and shape-only, shared
    by encode and decode so both sides agree without shipping widths."""
    block = min(cfg.codec_block, max(1, n_total))
    nb = -(-n_total // block)
    n_chunks = max(1, min(cfg.overlap_chunks, nb))
    step = -(-nb // n_chunks) * block
    return tuple(min(step, n_total - lo) for lo in range(0, n_total, step))


def _encode_bucket(cfg: SyncConfig, flat: jnp.ndarray, want_local: bool
                   ) -> Tuple[Tuple[ChunkPayload, ...],
                              Optional[jnp.ndarray]]:
    """Encode one bucket segment into wire chunks (+ local reconstruction).

    ``flat``: (n_pods, N_g).  One encode/decode pair is bound to this
    bucket's (block, tier) knobs — the per-bucket codec dispatch point.
    The permute of chunk i is data-independent of the encode of chunk i+1
    (``SyncConfig.overlap_chunks``): on a real mesh the transfer of one
    chunk hides behind the compression of the next, which is what
    ``MeshTransport.measure_overlap`` measures and the WAN simulator
    models."""
    from repro.kernels import ops as kops
    from repro.kernels.wan_codec import k_per_block

    n_total = flat.shape[1]
    block = min(cfg.codec_block, max(1, n_total))
    k_block = k_per_block(block, cfg.compress_topk)
    encode, decode = kops.wan_codec_fns(block=block,
                                        value_dtype=cfg.value_dtype)
    chunks, local_parts, off = [], [], 0
    for m in _chunk_widths(cfg, n_total):
        seg = flat[:, off:off + m]
        off += m
        q, idx, scales = jax.vmap(lambda f: encode(f, k_block))(seg)
        if want_local:
            local_parts.append(jax.vmap(
                lambda a, i, s: decode(a, i, s, m))(q, idx, scales))
        chunks.append(ChunkPayload(q=q, idx=idx.astype(jnp.uint16),
                                   scales=scales))
    local = jnp.concatenate(local_parts, axis=1) if want_local else None
    return tuple(chunks), local


def _decode_chunks(cfg: SyncConfig, chunks: Sequence[ChunkPayload],
                   widths: Sequence[int], n_total: int) -> jnp.ndarray:
    """Decode an explicit (chunk, width) list of one bucket's wire chunks.
    ``n_total`` is the width the bucket was *encoded* at — it fixes the
    codec block, so a chunk prefix decodes bit-identically whether or not
    the round shipped the rest of the bucket (chunks are independent)."""
    from repro.kernels import ops as kops

    block = min(cfg.codec_block, max(1, n_total))
    _, decode = kops.wan_codec_fns(block=block, value_dtype=cfg.value_dtype)
    parts = [jax.vmap(lambda a, i, s: decode(a, i, s, m))(
        c.q, c.idx.astype(jnp.int32), c.scales)
        for c, m in zip(chunks, widths)]
    return jnp.concatenate(parts, axis=1)


def _decode_bucket(cfg: SyncConfig, chunks: Sequence[ChunkPayload],
                   n_total: int) -> jnp.ndarray:
    """Decode one bucket's (shipped) wire chunks back to dense."""
    return _decode_chunks(cfg, chunks, _chunk_widths(cfg, n_total), n_total)


class TransferFailed(RuntimeError):
    """One bucket's ring transfer failed (timeout, drop, link fault) —
    retryable: :func:`ship_sync_payloads` re-ships the bucket up to the
    transport's ``retry_policy.max_retries`` before declaring the peer
    unreachable."""

    def __init__(self, bucket: str, attempt: int, reason: str = "",
                 pod: Optional[int] = None):
        self.bucket, self.attempt = bucket, attempt
        self.reason, self.pod = reason, pod
        super().__init__(
            f"transfer of bucket {bucket!r} failed on attempt {attempt}"
            + (f": {reason}" if reason else ""))


class CorruptPayloadError(TransferFailed):
    """Shipped wire chunks failed checksum verification — retryable (a
    re-send re-reads the sender's intact buffer)."""


class PodUnreachableError(RuntimeError):
    """Retries exhausted (or a pod crashed mid-round): the peer missed the
    sync barrier.  The round either completes degraded over the surviving
    membership mask (``finish_codec_sync(..., alive=...)``) or rolls back
    to the last sync barrier checkpoint — the launcher decides."""

    def __init__(self, pod: Optional[int] = None,
                 step: Optional[int] = None, bucket: str = ""):
        self.pod, self.step, self.bucket = pod, step, bucket
        where = f"pod {pod}" if pod is not None else "peer"
        at = f" at step {step}" if step is not None else ""
        via = f" (bucket {bucket!r})" if bucket else ""
        super().__init__(f"{where} unreachable{at}{via}: retries exhausted")


def chunk_checksum_rows(chunks: Sequence[ChunkPayload]) -> Tuple[int, ...]:
    """Per-pod-row CRC32 over one bucket's wire chunks (q ‖ idx ‖ scales
    bytes, chunk by chunk) — the wire-format integrity word the
    fault-tolerant ship path verifies after a transfer.  Host-side: pulls
    device buffers, so it only runs on host-seam transports (never inside
    a jit trace)."""
    import zlib

    n_pods = int(chunks[0].q.shape[0])
    out = []
    for p in range(n_pods):
        crc = 0
        for c in chunks:
            for part in (c.q, c.idx, c.scales):
                crc = zlib.crc32(
                    np.ascontiguousarray(np.asarray(part[p])).tobytes(), crc)
        out.append(crc)
    return tuple(out)


def verify_shipment(name: str, sent_crc: Sequence[int],
                    shipped: Sequence[ChunkPayload], shift: int) -> None:
    """Check a shipped bucket against pre-ship checksums: under the ring
    permute, shipped row ``p`` must be sender row ``(p - shift) % n``
    bit-for-bit.  Raises :class:`CorruptPayloadError` naming the first
    mismatching receiver row."""
    n = len(sent_crc)
    got = chunk_checksum_rows(shipped)
    for p in range(n):
        if got[p] != sent_crc[(p - shift) % n]:
            raise CorruptPayloadError(
                name, 0, f"checksum mismatch on receiver row {p}", pod=p)


class InlineRingShip:
    """The default transport: ring-permute each wire part in place, traced
    into the enclosing jit (-> one collective-permute per part under SPMD).
    Real transports (:mod:`repro.core.transport`) implement the same
    ``ship_bucket`` contract; this degenerate one is why ``transport=None``
    is bit-identical to the pre-seam inline path."""

    in_graph = True

    def ship_bucket(self, name: str, chunks: Sequence[ChunkPayload],
                    shift: int, payload_mb: float = 0.0
                    ) -> Tuple[ChunkPayload, ...]:
        del name, payload_mb
        return tuple(ChunkPayload(*(jnp.roll(p, shift, axis=0) for p in c))
                     for c in chunks)


_INLINE_RING = InlineRingShip()


def bucket_wire_mb(cfg: SyncConfig, layout: BucketLayout
                   ) -> Dict[str, float]:
    """Per-pod wire megabytes per non-empty bucket group for one sync round
    (host-side, static) — what transports bill/record per transfer."""
    return {name: cfg.for_bucket(name).payload_mb(
        layout.sizes[g] * 4 / 1e6)
        for g, name in enumerate(layout.names) if layout.sizes[g]}


def prepare_codec_sync(cfg: SyncConfig, state: SyncState) -> SyncPayloads:
    """The codec round's *decide/pack* stage (jit-able): average the
    accumulated gradient, fold in the EF residual, pack the bucket-grouped
    buffer and encode every non-empty bucket segment at its own (top-k,
    tier, block) knobs.  What comes out is exactly what a transport ships —
    ``apply_sync`` composes this with a ship and :func:`finish_codec_sync`,
    and the trainer's host-seam path runs the three stages as separate
    dispatches so a real transport can time each bucket's transfer."""
    denom = jnp.maximum(state.steps_since_sync, 1).astype(jnp.float32)
    avg = jax.tree.map(lambda b: b / denom, state.ga_buffer)
    layout = bucket_layout(cfg, avg)
    flat = _pack_stacked(avg, layout)
    if cfg.error_feedback:
        flat = flat + state.ef_residual
    chunks: Dict[str, Tuple[ChunkPayload, ...]] = {}
    local_parts = []
    for g, name in enumerate(layout.names):
        off, size = layout.offsets[g], layout.sizes[g]
        if size == 0:
            continue
        bchunks, local = _encode_bucket(cfg.for_bucket(name),
                                        flat[:, off:off + size],
                                        want_local=cfg.error_feedback)
        chunks[name] = bchunks
        if cfg.error_feedback:
            local_parts.append(local)
    local = (jnp.concatenate(local_parts, axis=1) if local_parts
             else (flat[:, :0] if cfg.error_feedback else None))
    return SyncPayloads(flat=flat, local=local, chunks=chunks)


def ship_sync_payloads(cfg: SyncConfig,
                       chunks: Mapping[str, Tuple[ChunkPayload, ...]],
                       transport=None,
                       wire_mb: Optional[Mapping[str, float]] = None
                       ) -> Dict[str, Tuple[ChunkPayload, ...]]:
    """Emit every bucket's wire chunks to the transport's one-peer ring
    send.  ``transport=None`` is the in-graph inline ring (bit-exact
    legacy path); a host-seam transport executes + times each bucket's
    transfer here.

    Fault tolerance rides the transport's optional attributes: a
    ``retry_policy`` (:class:`repro.core.wan.RetryPolicy`) bounds how many
    :class:`TransferFailed` raises per bucket are retried before
    :class:`PodUnreachableError`; ``verify_checksums`` (host-seam only)
    checksums each bucket pre-ship and verifies the shipped rows, so a
    corrupted payload is caught and re-shipped instead of decoded into
    the parameters.  Transports without these attributes get the original
    single-attempt path unchanged."""
    ship = transport if transport is not None else _INLINE_RING
    wire_mb = wire_mb or {}
    in_graph = getattr(ship, "in_graph", True)
    verify = bool(getattr(ship, "verify_checksums", False)) and not in_graph
    policy = getattr(ship, "retry_policy", None)
    max_retries = int(policy.max_retries) if policy is not None else 0
    note_retry = getattr(ship, "note_retry", None)
    out: Dict[str, Tuple[ChunkPayload, ...]] = {}
    for name, bchunks in chunks.items():
        sent_crc = chunk_checksum_rows(bchunks) if verify else None
        attempt = 0
        while True:
            try:
                shipped = ship.ship_bucket(name, bchunks, cfg.peer_shift,
                                           wire_mb.get(name, 0.0))
                if verify:
                    verify_shipment(name, sent_crc, shipped, cfg.peer_shift)
                break
            except TransferFailed as err:
                attempt += 1
                if attempt > max_retries:
                    raise PodUnreachableError(pod=err.pod,
                                              bucket=name) from err
                if note_retry is not None:
                    note_retry(name, attempt, err)
        out[name] = shipped
    return out


def finish_codec_sync(cfg: SyncConfig, params: Pytree, state: SyncState,
                      payloads: SyncPayloads,
                      shipped: Mapping[str, Tuple[ChunkPayload, ...]],
                      lr: Union[jnp.ndarray, float] = 1.0,
                      alive: Optional[jnp.ndarray] = None
                      ) -> Tuple[Pytree, SyncState]:
    """The codec round's tail (jit-able): decode the shipped chunks, apply
    the receiver-side SGD update, and roll the EF residual + per-bucket
    telemetry into the new :class:`SyncState`.

    ``alive`` (``(n_pods,)`` 1/0 mask, default all-alive) is the degraded
    round: a peer update is applied only where both the receiver and its
    ring sender are alive; a sender whose message never arrived (it died,
    or its receiver did) keeps the FULL message as its EF residual, so
    nothing sent into a dead link is lost — it redelivers next round, and
    a later pod shrink replay-accumulates it sum-preservingly
    (:func:`resize_sync_state`).  Undelivered rows' ``msg_norm`` /
    ``resid_norm`` zero out, which the adaptive controllers already read
    as "no reading yet" — a degraded round is evidence-free, never a
    spurious ef-guard trip."""
    layout = bucket_layout(cfg, state.ga_buffer)
    peer_parts = []
    for g, name in enumerate(layout.names):
        size = layout.sizes[g]
        if size == 0:
            peer_parts.append(payloads.flat[:, :0])
            continue
        peer_parts.append(_decode_bucket(cfg.for_bucket(name),
                                         shipped[name], size))
    peer_flat = jnp.concatenate(peer_parts, axis=1)
    return _finish_from_peer(cfg, params, state, payloads.flat,
                             payloads.local, peer_flat, layout, lr, alive)


def _finish_from_peer(cfg: SyncConfig, params: Pytree, state: SyncState,
                      flat: jnp.ndarray, local: Optional[jnp.ndarray],
                      peer_flat: jnp.ndarray, layout: BucketLayout,
                      lr: Union[jnp.ndarray, float],
                      alive: Optional[jnp.ndarray]
                      ) -> Tuple[Pytree, SyncState]:
    """Common tail of the codec round once the peer message is dense:
    alive masking, receiver SGD, EF rollover and telemetry.  ``local`` is
    the sender-side reconstruction of what the peer will decode — the
    full-round one on the plain path, the spliced prefix+tail one on the
    streaming retune path."""
    applied = delivered = None
    if alive is not None:
        alive = jnp.asarray(alive, jnp.float32)
        # receiver p applies iff p and its ring sender (p - shift) are alive
        applied = alive * jnp.roll(alive, cfg.peer_shift)
        # sender p's message arrived iff p and its receiver (p + shift) are
        delivered = alive * jnp.roll(alive, -cfg.peer_shift)
        peer_flat = peer_flat * applied[:, None]
    peer = _unpack_stacked(peer_flat, state.ga_buffer, layout)
    # per-pod, per-bucket message norms — with EF also the residual norms;
    # their ratio is the convergence signal the adaptive controllers guard
    # on (a bucket's residual growing toward its message norm means that
    # bucket's tier is dropping more than EF can recover per interval)
    msg_norm = _bucket_norms(flat, layout)
    new_resid, resid_norm = state.ef_residual, state.resid_norm
    if cfg.error_feedback:
        new_resid = flat - local
        if delivered is not None:
            new_resid = jnp.where(delivered[:, None] > 0, new_resid, flat)
        resid_norm = _bucket_norms(new_resid, layout)
    if delivered is not None:
        msg_norm = msg_norm * delivered[:, None]
        resid_norm = resid_norm * delivered[:, None]
    scale = jnp.asarray(lr, jnp.float32) * cfg.ga_lr_scale
    params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - scale * g).astype(p.dtype),
        params, peer)
    buf = jax.tree.map(jnp.zeros_like, state.ga_buffer)
    zero = state._replace(steps_since_sync=jnp.zeros((), jnp.int32))
    return params, zero._replace(ga_buffer=buf, ef_residual=new_resid,
                                 tier=jnp.asarray(cfg.bucket_tiers,
                                                  jnp.int32),
                                 msg_norm=msg_norm, resid_norm=resid_norm)


# ----------------------------------------------- streaming mid-round retune


def reencode_unsent(cfg: SyncConfig, cfg_to: SyncConfig, flat: jnp.ndarray,
                    layout: BucketLayout, sent: Mapping[str, int]
                    ) -> Tuple[Dict[str, Tuple[ChunkPayload, ...]],
                               Dict[str, jnp.ndarray]]:
    """Re-encode every bucket's *unsent* chunk tail at ``cfg_to``'s
    cheaper (topk, dtype) knobs — the streaming mid-round retune.

    ``sent`` maps bucket name -> number of ``cfg``-schedule chunks already
    shipped (buckets absent default to fully shipped).  Chunks split on
    codec-block boundaries and ``cfg_to`` carries ``cfg``'s ``codec_block``
    (the ladder only moves topk/dtype), so the sent prefix keeps its exact
    encoding and the tail re-encodes standalone: block-local selection
    never looks across the cut.  Returns ``(tail_chunks, tail_local)``
    keyed by bucket (only buckets with an unsent tail appear); the caller
    splices them into the round with :func:`finish_codec_sync_split`,
    whose EF rollover then *exactly* carries the tail's fidelity delta —
    the convergence guards' contract survives the retune."""
    tails: Dict[str, Tuple[ChunkPayload, ...]] = {}
    locals_: Dict[str, jnp.ndarray] = {}
    for g, name in enumerate(layout.names):
        off, size = layout.offsets[g], layout.sizes[g]
        if size == 0:
            continue
        widths = _chunk_widths(cfg.for_bucket(name), size)
        n_sent = sent.get(name, len(widths))
        sw = int(sum(widths[:n_sent]))
        if sw >= size:
            continue
        tchunks, tlocal = _encode_bucket(cfg_to.for_bucket(name),
                                         flat[:, off + sw:off + size],
                                         want_local=cfg.error_feedback)
        tails[name] = tchunks
        locals_[name] = tlocal
    return tails, locals_


def finish_codec_sync_split(cfg: SyncConfig, cfg_to: SyncConfig,
                            params: Pytree, state: SyncState,
                            payloads: SyncPayloads,
                            shipped: Mapping[str, Tuple[ChunkPayload, ...]],
                            tail_shipped: Mapping[str,
                                                  Tuple[ChunkPayload, ...]],
                            tail_local: Mapping[str, jnp.ndarray],
                            sent: Mapping[str, int],
                            lr: Union[jnp.ndarray, float] = 1.0,
                            alive: Optional[jnp.ndarray] = None
                            ) -> Tuple[Pytree, SyncState]:
    """Finish a streaming round that retuned mid-round: each bucket's
    peer message is the shipped ``cfg`` prefix chunks plus the shipped
    ``cfg_to`` tail chunks, and the sender-side reconstruction is spliced
    the same way — so ``ef_residual = flat - spliced_local`` carries
    exactly the fidelity the cheaper tail dropped.  The persistent config
    (and ``SyncState.tier`` telemetry) stays ``cfg``'s: the retune is
    transient, owned by this round alone."""
    layout = bucket_layout(cfg, state.ga_buffer)
    peer_parts, local_parts = [], []
    for g, name in enumerate(layout.names):
        off, size = layout.offsets[g], layout.sizes[g]
        if size == 0:
            peer_parts.append(payloads.flat[:, :0])
            continue
        bcfg = cfg.for_bucket(name)
        widths = _chunk_widths(bcfg, size)
        n_sent = sent.get(name, len(widths))
        sw = int(sum(widths[:n_sent]))
        parts, lparts = [], []
        if n_sent:
            parts.append(_decode_chunks(bcfg, shipped[name][:n_sent],
                                        widths[:n_sent], size))
            if cfg.error_feedback:
                lparts.append(payloads.local[:, off:off + sw])
        if sw < size:
            parts.append(_decode_bucket(cfg_to.for_bucket(name),
                                        tail_shipped[name], size - sw))
            if cfg.error_feedback:
                lparts.append(tail_local[name])
        peer_parts.append(parts[0] if len(parts) == 1
                          else jnp.concatenate(parts, axis=1))
        if cfg.error_feedback:
            local_parts.append(lparts[0] if len(lparts) == 1
                               else jnp.concatenate(lparts, axis=1))
    peer_flat = jnp.concatenate(peer_parts, axis=1)
    local = (jnp.concatenate(local_parts, axis=1) if local_parts
             else (payloads.flat[:, :0] if cfg.error_feedback else None))
    return _finish_from_peer(cfg, params, state, payloads.flat, local,
                             peer_flat, layout, lr, alive)


def bucket_chunk_mb(cfg: SyncConfig, layout: BucketLayout
                    ) -> Dict[str, Tuple[float, ...]]:
    """Per-chunk wire megabytes of each non-empty bucket (host-side,
    static) — the streaming ship's chunk schedule, summing to the bucket's
    :func:`bucket_wire_mb` entry up to float association."""
    out: Dict[str, Tuple[float, ...]] = {}
    for g, name in enumerate(layout.names):
        size = layout.sizes[g]
        if size == 0:
            continue
        bcfg = cfg.for_bucket(name)
        out[name] = tuple(bcfg.payload_mb(m * 4 / 1e6)
                          for m in _chunk_widths(bcfg, size))
    return out


def _bucket_norms(flat: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Per-pod, per-bucket L2 norms of a bucket-grouped buffer:
    (n_pods, n_buckets), zero columns for empty groups."""
    cols = [jnp.linalg.norm(flat[:, off:off + size], axis=1)
            if size else jnp.zeros((flat.shape[0],), jnp.float32)
            for off, size in zip(layout.offsets, layout.sizes)]
    return jnp.stack(cols, axis=1)


def _ship_ring(cfg: SyncConfig, tree: Pytree) -> Pytree:
    """One-peer ring send: roll along the pod dim (-> collective-permute)."""
    if 0.0 < cfg.compress_topk < 1.0:
        from repro.kernels import ops as kops

        # keep per-selection index spaces below int32 (trillion-param
        # accumulated-gradient leaves overflow a flat index otherwise)
        CHUNK = 1 << 26

        def ship(x):
            n_pods = x.shape[0]
            numel = int(np_prod(x.shape[1:]))
            pad = (-numel) % min(CHUNK, numel)
            chunk = min(CHUNK, numel)
            flat = x.reshape(n_pods, -1)
            if pad:
                flat = jnp.pad(flat, ((0, 0), (0, pad)))
            nch = flat.shape[1] // chunk
            k = max(1, int(chunk * cfg.compress_topk))
            f3 = flat.reshape(n_pods, nch, chunk)
            vals, idx = jax.vmap(jax.vmap(
                lambda f: kops.topk_compress(f, k)))(f3)
            vals = jnp.roll(vals, cfg.peer_shift, axis=0)
            idx = jnp.roll(idx, cfg.peer_shift, axis=0)
            dense = jax.vmap(jax.vmap(
                lambda v, i: kops.topk_decompress(v, i, chunk)))(vals, idx)
            dense = dense.reshape(n_pods, -1)
            if pad:
                dense = dense[:, :numel]
            return dense.reshape(x.shape)

        return jax.tree.map(ship, tree)
    return jax.tree.map(lambda x: jnp.roll(x, cfg.peer_shift, axis=0), tree)


def apply_sync(cfg: SyncConfig, params: Pytree, state: SyncState,
               lr: Union[jnp.ndarray, float] = 1.0, transport=None
               ) -> Tuple[Pytree, SyncState]:
    """One inter-pod synchronization round (paper §III.C steps 3-5).

    ``params`` leaves have the leading pod dim.  ``lr`` drives the
    receiver-side SGD update of ASGD-GA.  On the codec path the round is
    three stages — :func:`prepare_codec_sync` (decide/pack/encode),
    :func:`ship_sync_payloads` (the transport seam), and
    :func:`finish_codec_sync` (decode/update/EF) — and ``transport``
    selects who ships: ``None`` means the in-graph inline ring (bit-exact
    legacy behaviour, traceable); a host-seam transport
    (:class:`~repro.core.transport.MeshTransport`) executes and times each
    bucket's transfer, in which case this function must run OUTSIDE jit
    (the trainer's split path jits the prepare/finish stages separately).
    """
    n_pods = jax.tree.leaves(params)[0].shape[0]
    zero = state._replace(steps_since_sync=jnp.zeros((), jnp.int32))
    if n_pods <= 1 or cfg.strategy == "asgd":
        return params, zero

    if cfg.strategy == "asgd_ga":
        if cfg.uses_codec:
            # fused codec: bucket -> (+ EF residual) -> per-bucket top-k ->
            # quantize -> ship -> decode; the residual keeps everything the
            # codec dropped for re-injection at the next sync (EF-SGD)
            payloads = prepare_codec_sync(cfg, state)
            wire = bucket_wire_mb(cfg, bucket_layout(cfg, state.ga_buffer))
            shipped = ship_sync_payloads(cfg, payloads.chunks, transport,
                                         wire)
            return finish_codec_sync(cfg, params, state, payloads, shipped,
                                     lr)
        denom = jnp.maximum(state.steps_since_sync, 1).astype(jnp.float32)
        avg = jax.tree.map(lambda b: b / denom, state.ga_buffer)
        peer = _ship_ring(cfg, avg)
        scale = jnp.asarray(lr, jnp.float32) * cfg.ga_lr_scale
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - scale * g).astype(p.dtype),
            params, peer)
        buf = jax.tree.map(jnp.zeros_like, state.ga_buffer)
        return params, zero._replace(ga_buffer=buf,
                                     tier=jnp.asarray(cfg.bucket_tiers,
                                                      jnp.int32))

    if cfg.strategy == "asp":
        # Gaia-style Approximate Synchronous Parallel: ship only parameter
        # deltas whose relative magnitude since the last sync exceeds the
        # significance threshold (the paper's main comparison system,
        # implemented as a baseline).  Insignificant deltas keep accumulating
        # in place (params themselves carry them).
        eps = 1e-8
        ref = state.ga_buffer
        delta = jax.tree.map(
            lambda p, r: p.astype(jnp.float32) - r, params, ref)
        sig = jax.tree.map(
            lambda d, r: jnp.abs(d) > cfg.asp_threshold * (jnp.abs(r) + eps),
            delta, ref)
        shipped = jax.tree.map(
            lambda d, m: jnp.where(m, d, 0.0), delta, sig)
        n_sig = sum(jnp.sum(m) for m in jax.tree.leaves(sig))
        n_tot = sum(m.size for m in jax.tree.leaves(sig))
        frac = n_sig.astype(jnp.float32) / n_tot
        peer = _ship_ring(cfg, shipped)
        params = jax.tree.map(
            lambda p, q: (p.astype(jnp.float32) + 0.5 * q).astype(p.dtype),
            params, peer)
        new_ref = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return params, zero._replace(ga_buffer=new_ref,
                                     significant_frac=frac)

    if cfg.strategy == "ama":
        peer = _ship_ring(cfg, params)
        params = jax.tree.map(
            lambda p, q: ((p.astype(jnp.float32) + q.astype(jnp.float32)) * 0.5
                          ).astype(p.dtype),
            params, peer)
        return params, zero

    # sma — barrier global average
    params = jax.tree.map(
        lambda p: jnp.broadcast_to(
            jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True),
            p.shape).astype(p.dtype),
        params)
    return params, zero


def hierarchical_average(tree: Pytree, groups: Sequence[Sequence[int]],
                         inter: str = "ama", shift: int = 1) -> Pytree:
    """Two-level averaging: the existing strategies mapped onto hierarchy
    levels (paper §III.C's inter-PS model averaging across regions).

    ``groups`` partitions the pod axis into regions.  The intra level is a
    barrier mean within each region (``sma`` semantics over the region's
    fast fabric); the inter level exchanges the *region means*: ``ama``
    gossips them one ring step (MA between region parameter servers),
    ``sma`` takes their global mean.  The result is broadcast back to
    every member.

    Degenerate shapes recover the flat strategies exactly (property-tested
    in ``tests/test_topology.py``): all-singleton groups in pod order with
    ``inter="ama"`` reproduce flat ``ama`` bit-for-bit (a size-one mean is
    the identity, and the region ring is then the pod ring), and a single
    group reproduces flat ``sma`` (the inter level collapses to the
    identity on the one region mean)."""
    groups = tuple(tuple(int(i) for i in g) for g in groups)
    if not groups or any(not g for g in groups):
        raise ValueError("groups must be non-empty and cover every pod")
    members = [i for g in groups for i in g]
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree
    n_pods = leaves[0].shape[0]
    if sorted(members) != list(range(n_pods)):
        raise ValueError(f"groups {groups} do not partition pods "
                         f"0..{n_pods - 1}")
    n_groups = len(groups)
    if inter not in ("ama", "sma"):
        raise ValueError(f"inter level must be 'ama' or 'sma', got {inter!r}")
    if inter == "ama" and n_groups > 1 and math.gcd(shift, n_groups) != 1:
        raise ValueError(f"inter-ring shift {shift} must be coprime with "
                         f"the number of regions {n_groups}")
    # pod i receives the aggregate of the group it belongs to
    assign = np.empty(n_pods, dtype=np.int32)
    for gi, g in enumerate(groups):
        assign[list(g)] = gi
    assign = jnp.asarray(assign)
    gathers = [jnp.asarray(g, dtype=jnp.int32) for g in groups]

    def avg(p):
        x = p.astype(jnp.float32)
        m = jnp.stack([jnp.mean(x[idx], axis=0) for idx in gathers])
        if inter == "ama":
            m = (m + jnp.roll(m, shift, axis=0)) * 0.5
        else:
            m = jnp.broadcast_to(jnp.mean(m, axis=0, keepdims=True), m.shape)
        return m[assign].astype(p.dtype)

    return jax.tree.map(avg, tree)


# ---------------------------------------------------------------------------
# pod-count-changing state transforms (elasticity engine)
# ---------------------------------------------------------------------------
#
# A reconfiguration (cloud joined / left) changes ``n_pods`` mid-run.  Under
# the stacked representation that is a resize of every leaf's leading pod
# dimension, applied at a sync barrier.  Two families:
#
# - parameter-like leaves ("mean" semantics): the global parameter mean must
#   be preserved — new pods are seeded with the mean replica on grow, and on
#   shrink the survivors are shifted so their mean equals the old global mean
#   (removed pods' progress is re-averaged in, not discarded).
# - accumulator-like leaves ("sum" semantics, the ASGD-GA gradient buffer):
#   the *total* accumulated gradient must be preserved — new pods start at
#   zero on grow, and on shrink the removed pods' accumulations are
#   replay-distributed evenly across the survivors.


def grow_pods(tree: Pytree, n_new: int, how: str = "mean") -> Pytree:
    """Grow the leading pod dimension to ``n_new`` (>= current).

    ``how``: "mean" appends mean-of-existing replicas (preserves the global
    parameter mean), "clone" appends copies of pod 0, "zeros" appends zero
    pods (preserves accumulator totals).
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree   # stateless (e.g. plain-SGD optimizer state)
    n_old = leaves[0].shape[0]
    if n_new < n_old:
        raise ValueError(f"grow_pods: {n_new} < current {n_old}")
    if n_new == n_old:
        return tree
    k = n_new - n_old

    def grow(x):
        if x.ndim == 0 or x.shape[0] != n_old:
            return x   # scalar bookkeeping leaf, no pod dim
        if how == "mean":
            fill = jnp.broadcast_to(
                jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True),
                (k,) + x.shape[1:]).astype(x.dtype)
        elif how == "clone":
            fill = jnp.broadcast_to(x[:1], (k,) + x.shape[1:])
        elif how == "zeros":
            fill = jnp.zeros((k,) + x.shape[1:], x.dtype)
        else:
            raise ValueError(f"grow_pods: unknown how={how!r}")
        return jnp.concatenate([x, fill], axis=0)

    return jax.tree.map(grow, tree)


def shrink_pods(tree: Pytree, keep: Sequence[int], how: str = "mean") -> Pytree:
    """Shrink the leading pod dimension to the pods in ``keep`` (ordered).

    ``how``: "mean" shifts survivors so their mean equals the old global mean
    (re-averaging the departed pods' progress in), "sum" redistributes the
    removed pods' values evenly over survivors (preserves the total —
    replay-accumulate for gradient buffers), "drop" discards removed pods.
    """
    keep = tuple(int(i) for i in keep)
    if not keep:
        raise ValueError("shrink_pods: keep must be non-empty")
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree   # stateless (e.g. plain-SGD optimizer state)
    n_old = leaves[0].shape[0]
    if any(i < 0 or i >= n_old for i in keep):
        raise ValueError(f"shrink_pods: keep {keep} out of range for {n_old}")
    if len(set(keep)) != len(keep):
        raise ValueError("shrink_pods: duplicate indices in keep")
    removed = tuple(i for i in range(n_old) if i not in keep)
    idx = jnp.asarray(keep)

    def shrink(x):
        if x.ndim == 0 or x.shape[0] != n_old:
            return x
        kept = jnp.take(x, idx, axis=0)
        if how == "drop" or not removed:
            return kept
        xf = x.astype(jnp.float32)
        kf = kept.astype(jnp.float32)
        if how == "mean":
            shift = (jnp.mean(xf, axis=0, keepdims=True)
                     - jnp.mean(kf, axis=0, keepdims=True))
            return (kf + shift).astype(x.dtype)
        if how == "sum":
            lost = jnp.sum(jnp.take(xf, jnp.asarray(removed), axis=0),
                           axis=0, keepdims=True)
            return (kf + lost / len(keep)).astype(x.dtype)
        raise ValueError(f"shrink_pods: unknown how={how!r}")

    return jax.tree.map(shrink, tree)


def resize_sync_state(cfg: SyncConfig, state: SyncState, new_params: Pytree,
                      keep: Optional[Sequence[int]] = None) -> SyncState:
    """Carry ``SyncState`` across a pod-count change.

    ``new_params`` are the already-resized stacked parameters.  Strategy
    semantics: ASGD-GA replay-accumulates the departed pods' gradient buffer
    into the survivors (sum-preserving) and zero-seeds joiners; ASP resets
    its reference to the new parameters (deltas restart from the
    reconfigured model); the bufferless strategies just re-init.
    """
    n_new = jax.tree.leaves(new_params)[0].shape[0]
    if cfg.strategy == "asgd_ga":
        buf = state.ga_buffer
        n_old = jax.tree.leaves(buf)[0].shape[0] if jax.tree.leaves(buf) else 0
        # the EF residual is accumulator-like (sum semantics): departed
        # pods' un-retransmitted error is replay-distributed, joiners start
        # with none
        resid = state.ef_residual
        if keep is not None and len(keep) < n_old:
            buf = shrink_pods(buf, keep, how="sum")
            resid = shrink_pods([resid], keep, how="sum")[0]
            n_old = len(keep)
        if n_new > n_old:
            buf = grow_pods(buf, n_new, how="zeros")
            resid = grow_pods([resid], n_new, how="zeros")[0]
        # msg/resid norms are transient telemetry of the *last* sync round:
        # a pod-count change invalidates them, so they re-arm at zero (the
        # adaptive controllers treat zeros as "no reading yet"); the active
        # per-bucket tiers survive the resize untouched, and the bucket
        # partition itself is pod-count-independent (it is a property of
        # the per-pod leaf shapes), so the grouped EF-residual segments
        # stay aligned through the pod-axis grow/shrink above
        nb = len(cfg.bucket_names)
        return state._replace(
            ga_buffer=buf, ef_residual=resid,
            msg_norm=jnp.zeros((n_new, nb), jnp.float32),
            resid_norm=jnp.zeros((n_new, nb), jnp.float32))
    fresh = init_sync_state(cfg, new_params)
    return fresh._replace(steps_since_sync=state.steps_since_sync,
                          significant_frac=state.significant_frac,
                          tier=state.tier)


def retune_sync_state(new_cfg: SyncConfig, old_cfg: SyncConfig,
                      state: SyncState, stacked_params: Pytree) -> SyncState:
    """Carry ``SyncState`` across a *codec retune* (same strategy and pod
    count, different tier / top-k / interval — the adaptive controller's
    reconfiguration path).

    The EF residual is the one buffer whose meaning survives a tier change:
    it is defined in dense bucket coordinates (message minus what the peer
    reconstructed), independent of how the next message will be encoded —
    re-injecting it under the new tier is exactly EF-SGD semantics, and
    each bucket group's segment carries over *independently* (a retune
    that moves only the MoE bucket's tier leaves every other bucket's
    residual bytes untouched).  When the retune changes the bucket
    *policy* (single <-> layer-class) the grouped buffer order changes,
    so the residual is re-permuted leaf-chunk by leaf-chunk into the new
    layout — no residual mass is dropped.  It is dropped only when the
    new config stops tracking it (EF off) and zero-seeded when EF turns
    on.
    """
    if new_cfg.strategy != old_cfg.strategy:
        raise ValueError(
            f"retune cannot change strategy ({old_cfg.strategy!r} -> "
            f"{new_cfg.strategy!r}); that is a reconfiguration "
            f"(resize_sync_state / Trainer.reconfigure)")
    n_pods = jax.tree.leaves(stacked_params)[0].shape[0]
    want_ef = new_cfg.uses_codec and new_cfg.error_feedback
    had_ef = state.ef_residual.shape[1] > 0
    if want_ef and not had_ef:
        n = sum(x.size for x in jax.tree.leaves(stacked_params)) // n_pods
        resid = jnp.zeros((n_pods, n), jnp.float32)
    elif not want_ef:
        resid = jnp.zeros((n_pods, 0), jnp.float32)
    else:
        resid = state.ef_residual
        old_layout = bucket_layout(old_cfg, stacked_params)
        new_layout = bucket_layout(new_cfg, stacked_params)
        if old_layout.order != new_layout.order:
            # policy change re-groups the buffer: move each leaf's chunk
            # from its old offset to its new packing position
            old_off = old_layout.leaf_offsets
            resid = jnp.concatenate(
                [resid[:, old_off[i]:old_off[i] + old_layout.leaf_sizes[i]]
                 for i in new_layout.order], axis=1)
    nb_new, nb_old = len(new_cfg.bucket_names), len(old_cfg.bucket_names)
    msg_norm, resid_norm = state.msg_norm, state.resid_norm
    if nb_new != nb_old:
        # telemetry columns are per-bucket: a policy change re-arms them
        # at zero ("no reading yet") rather than mislabeling old readings
        msg_norm = jnp.zeros((n_pods, nb_new), jnp.float32)
        resid_norm = jnp.zeros((n_pods, nb_new), jnp.float32)
    return state._replace(ef_residual=resid,
                          tier=jnp.asarray(new_cfg.bucket_tiers, jnp.int32),
                          msg_norm=msg_norm, resid_norm=resid_norm)


# ---------------------------------------------------------------------------
# host-side schedule + traffic model
# ---------------------------------------------------------------------------


def is_sync_step(cfg: SyncConfig, step: int) -> bool:
    """Host-loop predicate: run ``apply_sync`` after this step?"""
    if cfg.strategy == "asgd":
        return False   # folded into every step's gradient reduction
    return (step + 1) % cfg.interval == 0


def traffic_per_step_mb(cfg: SyncConfig, model_mb: float,
                        bucket_weights: Optional[Mapping[str, float]] = None
                        ) -> float:
    """Average inter-pod WAN traffic per training step per pod.

    ``bucket_weights`` (from :func:`bucket_weights_of`) makes a
    layer-class config's accounting exact — each bucket group is billed
    at its own tier."""
    if cfg.strategy == "asgd":
        return model_mb
    return cfg.payload_mb(model_mb, bucket_weights=bucket_weights) \
        / cfg.interval


def migration_wire_mb(stacked_params: Pytree, n_new: int) -> float:
    """WAN bytes a *live* pod migration stages in the background.

    Each joining pod pulls one full fp32 per-pod replica from the last
    durable snapshot; each leaving pod pushes one replica-sized payload
    (its parameters + accumulator state folds into the survivors'
    sum-preserving resize).  Surviving pods move nothing — their state
    never leaves the device.  This traffic overlaps with training (the
    engine streams it off the step path), so the DES bills it as
    background ``traffic_mb``, not as pause; the only stall left is the
    one barrier-aligned reconcile."""
    n_old = jax.tree.leaves(stacked_params)[0].shape[0]
    per_pod_mb = sum(
        x.size * 4 for x in jax.tree.leaves(stacked_params)) / n_old / 1e6
    return per_pod_mb * abs(n_new - n_old)
