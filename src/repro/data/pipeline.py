"""Data pipeline: synthetic token streams + geo-partitioned datasets.

Two layers:

1. ``TokenStream`` — deterministic synthetic LM data (per-shard PRNG, no
   disk), shaped like a real tokenized corpus: (tokens, labels=shifted,
   mask).  Used by examples, benchmarks and the end-to-end driver.
2. ``GeoDataset`` — the paper's *pre-existing, unevenly distributed* training
   data: one shard per cloud/pod with an arbitrary distribution ratio
   (e.g. 2:1 between Shanghai/Chongqing).  The elastic scheduler consumes
   the shard sizes; per-pod loaders draw only from their own shard, which is
   what makes inter-pod sync a *model* sync rather than a data exchange —
   the paper's federated-ish constraint.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class TokenStream:
    """Deterministic synthetic LM token stream."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    # structured-synthetic mode: tokens follow a learnable bigram process so
    # training loss actually decreases (used by convergence tests)
    structured: bool = True

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + self.shard) * 1_000_003 + step)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S, V = self.batch_size, self.seq_len + 1, self.vocab_size
        if self.structured:
            # bigram next = (3 * tok + noise) % V : learnable structure
            toks = np.empty((B, S), np.int32)
            toks[:, 0] = rng.integers(0, V, size=B)
            noise = (rng.random((B, S)) < 0.1)
            rand = rng.integers(0, V, size=(B, S))
            for t in range(1, S):
                nxt = (3 * toks[:, t - 1] + 1) % V
                toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        else:
            toks = rng.integers(0, V, size=(B, S)).astype(np.int32)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((B, S - 1), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


# ---------------------------------------------------------------------------
# supervised synthetic sets for the paper's reference models
# ---------------------------------------------------------------------------


def synthetic_classification(
    n: int, input_shape: Tuple[int, ...], n_classes: int, seed: int = 0,
    feature_vocab: Optional[int] = None, task_seed: int = 1234,
) -> Dict[str, np.ndarray]:
    """A learnable synthetic classification set (class-conditional means for
    image-shaped inputs; class-correlated categorical ids for DeepFM-style
    inputs).  ``task_seed`` fixes the underlying concept (class means /
    prototype ids) so different ``seed`` draws are train/test splits of the
    *same* task."""
    task_rng = np.random.default_rng(task_seed)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    if feature_vocab is not None:
        fields = input_shape[0]
        base = task_rng.integers(0, feature_vocab, size=(n_classes, fields))
        x = base[y]
        flip = rng.random((n, fields)) < 0.25
        x = np.where(flip, rng.integers(0, feature_vocab, size=(n, fields)), x)
        return {"x": x.astype(np.int32), "y": y}
    means = task_rng.normal(0, 1, size=(n_classes,) + input_shape).astype(np.float32)
    x = means[y] + rng.normal(0, 1.2, size=(n,) + input_shape).astype(np.float32)
    return {"x": x, "y": y}


# ---------------------------------------------------------------------------
# geo-partitioned dataset
# ---------------------------------------------------------------------------


@dataclass
class GeoShard:
    region: str
    data: Dict[str, np.ndarray]

    @property
    def size(self) -> int:
        return len(self.data["y"])


@dataclass
class GeoDataset:
    """Pre-existing data distributed across clouds with a given ratio."""

    shards: List[GeoShard]

    @classmethod
    def partition(cls, data: Dict[str, np.ndarray], regions: Sequence[str],
                  ratio: Sequence[float], seed: int = 0) -> "GeoDataset":
        n = len(data["y"])
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        total = sum(ratio)
        counts = [int(n * r / total) for r in ratio]
        counts[-1] = n - sum(counts[:-1])
        shards, off = [], 0
        for region, c in zip(regions, counts):
            idx = perm[off:off + c]
            off += c
            shards.append(GeoShard(region,
                                   {k: v[idx] for k, v in data.items()}))
        return cls(shards)

    def sizes(self) -> Dict[str, int]:
        return {s.region: s.size for s in self.shards}

    def loader(self, region: str, batch_size: int, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
        shard = next(s for s in self.shards if s.region == region)
        rng = np.random.default_rng(seed)
        n = shard.size
        while True:
            idx = rng.integers(0, n, size=batch_size)
            yield {k: v[idx] for k, v in shard.data.items()}

    def epoch_batches(self, region: str, batch_size: int) -> int:
        shard = next(s for s in self.shards if s.region == region)
        return max(1, shard.size // batch_size)
