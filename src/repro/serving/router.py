"""Geo-aware request routing for the serving plane.

The :class:`GeoRouter` places each incoming request on a regional replica
by scoring, per candidate, the same three quantities the training plane
already models:

- **network seconds** — request+response wire size over the *measured*
  belief of the client-region -> replica-region link
  (:class:`~repro.core.topology.LinkBeliefs`, the per-link generalization
  of ``MeasuredWanProbe``: EMA with cliff-snap, so one observation of a
  collapsed link reroutes traffic before the next request pays for it);
- **compute + queue seconds** — tokens to generate over the replica's
  service rate, derived from the scheduler catalog's device power
  (``CATALOG[device].power()``, paper Table I), plus the tokens already
  queued on that replica at the same rate;
- **cost** — the catalog device's ``cost_per_unit_hour`` divided by its
  service rate: dollars per generated token.

Three modes pick the objective: ``nearest`` minimizes network seconds,
``cheapest`` minimizes cost per token, ``balanced`` minimizes total
request latency (network + queue + compute).  Every mode breaks ties
deterministically (score, then region name), and every placement is
recorded as a plain-dict :attr:`decisions` entry with the full score
table — `benchmarks/serving.py` commits the stream and
`check_regression.py` replays it through a fresh router via
:func:`replay_decisions`, the same recorded-decision discipline as the
topology planner and fault resolver.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.scheduler import CATALOG
from repro.core.topology import LinkBeliefs

ROUTER_MODES = ("nearest", "cheapest", "balanced")


@dataclass(frozen=True)
class ReplicaSpec:
    """One serving replica: a pod in some region running one slot pool."""

    region: str
    device: str = "v5e"            # scheduler-catalog device type
    units: int = 1                 # device units backing the replica
    n_slots: int = 4               # slot-pool width of its engine
    cost_per_unit_hour: float = 1.0

    def __post_init__(self):
        if self.device not in CATALOG:
            raise ValueError(f"unknown device {self.device!r} "
                             f"(catalog: {sorted(CATALOG)})")
        if self.units < 1:
            raise ValueError("units must be >= 1")

    @property
    def service_rate(self) -> float:
        """Relative tokens/sec: catalog compute power x units (TN for
        devices without a measured iteration time, IN otherwise — the
        same normalization Algorithm 1 plans with)."""
        return CATALOG[self.device].power() * self.units

    @property
    def cost_per_token(self) -> float:
        """Relative $/token: unit-hours burned per unit of service rate."""
        return self.units * self.cost_per_unit_hour / self.service_rate


class GeoRouter:
    """Places requests on regional replicas; see module docstring.

    Determinism contract: identical (replicas, mode, knobs) + identical
    event sequence (``observe_transfer`` / ``route`` / ``complete`` calls
    in order) => identical decision stream.  All state is explicit — link
    beliefs and per-replica outstanding tokens — and scores are rounded
    before recording so JSON round-trips are exact."""

    def __init__(self, replicas: Sequence[ReplicaSpec], *,
                 mode: str = "balanced", default_mbps: float = 100.0,
                 alpha: float = 0.5, cliff_snap: float = 4.0,
                 mb_per_token: float = 0.004):
        if mode not in ROUTER_MODES:
            raise ValueError(f"mode must be one of {ROUTER_MODES}")
        if not replicas:
            raise ValueError("need at least one replica")
        regions = [r.region for r in replicas]
        if len(set(regions)) != len(regions):
            raise ValueError(f"duplicate replica regions in {regions}")
        self.replicas: Dict[str, ReplicaSpec] = {
            r.region: r for r in sorted(replicas, key=lambda r: r.region)}
        self.mode = mode
        self.mb_per_token = float(mb_per_token)
        self.links = LinkBeliefs(default_mbps=default_mbps, alpha=alpha,
                                 cliff_snap=cliff_snap)
        self.outstanding: Dict[str, int] = {r: 0 for r in self.replicas}
        self._placed: Dict[int, str] = {}      # rid -> region
        self.decisions: List[dict] = []

    # ----------------------------------------------------------- beliefs
    def observe_transfer(self, a: str, b: str, payload_mb: float,
                         seconds: float) -> None:
        """Fold one measured client<->replica transfer into the a<->b link
        belief (same degenerate-sample rule as ``MeasuredWanProbe``:
        zero-byte or zero-time samples are dropped, not folded)."""
        if payload_mb <= 0.0 or seconds <= 0.0:
            return
        self.links.observe(a, b, payload_mb * 8.0 / seconds)

    # ----------------------------------------------------------- scoring
    def _score(self, spec: ReplicaSpec, src: str, prompt_len: int,
               max_new: int) -> Dict[str, float]:
        wire_mb = (prompt_len + max_new) * self.mb_per_token
        if src == spec.region:
            net_s = 0.0
        else:
            net_s = wire_mb * 8.0 / self.links.mbps(src, spec.region)
        compute_s = max_new / spec.service_rate
        queue_s = self.outstanding[spec.region] / spec.service_rate
        return {
            "net_s": round(net_s, 9),
            "compute_s": round(compute_s, 9),
            "queue_s": round(queue_s, 9),
            "total_s": round(net_s + compute_s + queue_s, 9),
            "cost_per_token": round(spec.cost_per_token, 9),
        }

    def _objective(self, s: Dict[str, float]) -> tuple:
        if self.mode == "nearest":
            return (s["net_s"], s["queue_s"])
        if self.mode == "cheapest":
            return (s["cost_per_token"], s["net_s"], s["queue_s"])
        return (s["total_s"], s["cost_per_token"])

    # ----------------------------------------------------------- routing
    def route(self, rid: int, src: str, prompt_len: int, max_new: int
              ) -> str:
        """Place request ``rid`` from client region ``src``; returns the
        chosen replica region and records the full decision."""
        if rid in self._placed:
            raise ValueError(f"rid {rid} already routed")
        scores = {region: self._score(spec, src, prompt_len, max_new)
                  for region, spec in self.replicas.items()}
        chosen = min(scores,
                     key=lambda r: self._objective(scores[r]) + (r,))
        self.outstanding[chosen] += max_new
        self._placed[rid] = chosen
        s = scores[chosen]
        self.decisions.append({
            "rid": rid, "src": src, "mode": self.mode, "chosen": chosen,
            "prompt_len": int(prompt_len), "max_new": int(max_new),
            "scores": scores,
            "reason": (f"{self.mode}: {chosen} (net {s['net_s']:.4f}s + "
                       f"queue {s['queue_s']:.4f}s + compute "
                       f"{s['compute_s']:.4f}s, {s['cost_per_token']:.4f} "
                       f"$/tok)"),
        })
        return chosen

    def complete(self, rid: int) -> str:
        """Mark ``rid`` finished: release its queued tokens on the replica
        that served it."""
        region = self._placed.pop(rid, None)
        if region is None:
            raise KeyError(f"rid {rid} was never routed (or already "
                           f"completed)")
        spec_max = next(d["max_new"] for d in reversed(self.decisions)
                        if d["rid"] == rid)
        self.outstanding[region] = max(0, self.outstanding[region]
                                       - spec_max)
        return region

    # ------------------------------------------------------------ replay
    def snapshot(self) -> dict:
        """JSON-ready router state for bench baselines."""
        return {
            "mode": self.mode,
            "replicas": [{"region": r.region, "device": r.device,
                          "units": r.units, "n_slots": r.n_slots,
                          "cost_per_unit_hour": r.cost_per_unit_hour}
                         for r in self.replicas.values()],
            "outstanding": dict(self.outstanding),
            "links": {f"{a}<->{b}": est.bandwidth_mbps
                      for (a, b), est in sorted(self.links._est.items())},
        }


def replay_decisions(replicas: Sequence[ReplicaSpec], mode: str,
                     events: Iterable[dict], **router_kw) -> List[dict]:
    """Drive a fresh :class:`GeoRouter` through a recorded event stream
    and return its decision list — the serving plane's exact-replay gate.

    ``events`` entries: ``{"op": "observe", "a", "b", "payload_mb",
    "seconds"}``, ``{"op": "route", "rid", "src", "prompt_len",
    "max_new"}``, ``{"op": "complete", "rid"}``."""
    router = GeoRouter(replicas, mode=mode, **router_kw)
    for ev in events:
        op = ev["op"]
        if op == "observe":
            router.observe_transfer(ev["a"], ev["b"], ev["payload_mb"],
                                    ev["seconds"])
        elif op == "route":
            router.route(ev["rid"], ev["src"], ev["prompt_len"],
                         ev["max_new"])
        elif op == "complete":
            router.complete(ev["rid"])
        else:
            raise ValueError(f"unknown router event op {op!r}")
    return router.decisions
