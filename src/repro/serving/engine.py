"""Batched serving engine: prefill + KV-cache decode.

Serves a model with batched requests (the inference counterpart used by the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` input shapes).  The decode
cache kinds come from the model config: ring-buffer KV for sliding-window
positions, full KV for global positions, O(1) recurrent state for SSM
positions — so ``long_500k`` is served with bounded memory by SSM/hybrid/
local-attention architectures.

Serving is per-pod independent (the paper's technique synchronizes
*training* state; serving replicas don't synchronize), so the engine has no
pod dimension — on a multi-pod mesh each pod serves its own replica.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import Arch
from repro.models.registry import get_model_fns

Pytree = Any


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_new)
    steps: int
    prefill_len: int


class ServingEngine:
    def __init__(self, arch: Arch, params: Pytree, *,
                 cache_len: int = 1024, use_smoke: bool = False):
        self.arch = arch
        self.cfg = arch.smoke if use_smoke else arch.config
        self.fns = get_model_fns(arch.module)
        self.params = params
        self.cache_len = cache_len
        self._decode = jax.jit(
            lambda p, t, c, pos: self.fns.decode_step(p, self.cfg, t, c, pos))

    # ------------------------------------------------------------- prefill
    def prefill(self, tokens: jnp.ndarray, **extras) -> Tuple[jnp.ndarray, Pytree]:
        """tokens: (B, S) prompt. Returns (last-token logits, cache)."""
        if self.arch.module == "encdec":
            enc = extras["audio_emb"]
            from repro.models import encdec
            cache = encdec.init_cache(self.cfg, tokens.shape[0], self.cache_len,
                                      enc=jnp.asarray(enc, self.cfg.dtype("compute")),
                                      params=self.params)
            logits = None
            pos = jnp.int32(0)
            for i in range(tokens.shape[1]):   # teacher-forced prompt feed
                logits, cache = self._decode(self.params, tokens[:, i:i + 1],
                                             cache, pos)
                pos = pos + 1
            return logits[:, 0], cache
        logits, cache = jax.jit(
            lambda p, t: self.fns.prefill(p, self.cfg, t, self.cache_len,
                                          patch_emb=extras.get("patch_emb"))
        )(self.params, tokens)
        return logits, cache

    # -------------------------------------------------------------- decode
    def generate(self, prompt: jnp.ndarray, n_new: int, *,
                 temperature: float = 0.0, key=None, **extras
                 ) -> GenerationResult:
        B, S = prompt.shape
        logits, cache = self.prefill(prompt, **extras)
        pos = jnp.int32(S)
        out = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache, pos)
            pos = pos + 1
            tok = self._sample(logits[:, 0], temperature, key, i + 1)
        return GenerationResult(tokens=np.concatenate(out, axis=1),
                                steps=n_new, prefill_len=S)

    def _sample(self, logits: jnp.ndarray, temperature: float, key, i: int
                ) -> jnp.ndarray:
        logits = logits[:, : self.cfg.vocab_size]   # strip padded vocab
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key if key is not None else jax.random.key(0), i)
        return jax.random.categorical(
            k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


# ---------------------------------------------------------------------------
# request batching (simple continuous-batching front)
# ---------------------------------------------------------------------------


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,)
    max_new: int
    done: bool = False
    output: Optional[np.ndarray] = None


class BatchScheduler:
    """Greedy static batcher: groups pending requests into fixed-size decode
    batches (right-padded prompts), runs them to completion."""

    def __init__(self, engine: ServingEngine, batch_size: int):
        self.engine = engine
        self.batch_size = batch_size
        self.queue: List[Request] = []

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = len(self.queue)
        self.queue.append(Request(rid, prompt, max_new))
        return rid

    def run(self) -> Dict[int, np.ndarray]:
        results: Dict[int, np.ndarray] = {}
        pending = [r for r in self.queue if not r.done]
        for i in range(0, len(pending), self.batch_size):
            group = pending[i:i + self.batch_size]
            S = max(len(r.prompt) for r in group)
            n_new = max(r.max_new for r in group)
            prompts = np.stack([
                np.pad(r.prompt, (S - len(r.prompt), 0)) for r in group])
            gen = self.engine.generate(jnp.asarray(prompts, jnp.int32), n_new)
            for j, r in enumerate(group):
                r.done = True
                r.output = gen.tokens[j, : r.max_new]
                results[r.rid] = r.output
        return results
