"""Serving plane: prefill + KV-cache decode, continuous batching on top.

Three layers, bottom to top:

- :class:`ServingEngine` — per-pod prefill + decode primitives (the
  inference counterpart of the ``prefill_32k`` / ``decode_32k`` /
  ``long_500k`` input shapes).  The decode cache kinds come from the model
  config: ring-buffer KV for sliding-window positions, full KV for global
  positions, O(1) recurrent state for SSM positions.
- :class:`ContinuousEngine` — a fixed **slot pool** over one decode cache
  whose batch axis is the pool (the maxengine/JetStream
  prefill → insert → generate decomposition): each request is prefilled
  *solo* (no padding — exactly its own tokens build its cache), inserted
  into a free slot with ``dynamic_update_slice`` on the cache's batch
  axis, and decoded by a per-slot ``vmap`` that gives every slot its own
  cache position.  Slots are row-independent under ``vmap``, so a slot's
  decoded tokens are bit-identical whether or not another slot was
  inserted or evicted mid-flight (property-tested in
  ``tests/test_serving.py``).
- :class:`ContinuousScheduler` / :class:`BatchScheduler` — request-level
  scheduling.  The continuous scheduler keeps *decoupled prefill and
  decode queues*: at most one prefill is admitted between decode steps,
  so a burst of long prompts never stalls the decode throughput of
  requests already in flight.  The batch scheduler is the run-to-
  completion baseline (`benchmarks/serving.py` measures the gap): it
  fills a group of slots, decodes the whole group to completion, and only
  then admits the next group.

Serving is per-pod independent (the paper's technique synchronizes
*training* state; serving replicas don't synchronize), so the engines have
no pod dimension — on a multi-pod mesh each pod serves its own replica,
and ``repro.serving.router.GeoRouter`` decides which replica a request
lands on.

Historical note: the pre-continuous ``BatchScheduler`` left-padded mixed-
length prompts with zeros and fed the pad tokens to ``prefill`` unmasked,
shifting positions and polluting the KV cache of every short prompt in the
batch.  The slot decomposition removes padding from the data path entirely
(each prompt prefills at its true length); the regression test
``test_batch_matches_solo_generation`` pins batched output token-for-token
to solo generation.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import Arch
from repro.models.registry import get_model_fns

Pytree = Any


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_new)
    steps: int
    prefill_len: int


class ServingEngine:
    def __init__(self, arch: Arch, params: Pytree, *,
                 cache_len: int = 1024, use_smoke: bool = False):
        self.arch = arch
        self.cfg = arch.smoke if use_smoke else arch.config
        self.fns = get_model_fns(arch.module)
        self.params = params
        self.cache_len = cache_len
        self._decode = jax.jit(
            lambda p, t, c, pos: self.fns.decode_step(p, self.cfg, t, c, pos))
        self._prefill = jax.jit(
            lambda p, t, pe: self.fns.prefill(p, self.cfg, t, self.cache_len,
                                              patch_emb=pe)
        ) if self.fns.prefill is not None else None

    # ------------------------------------------------------------- prefill
    def prefill(self, tokens: jnp.ndarray, **extras) -> Tuple[jnp.ndarray, Pytree]:
        """tokens: (B, S) prompt. Returns (last-token logits, cache)."""
        if self.arch.module == "encdec":
            enc = extras["audio_emb"]
            from repro.models import encdec
            cache = encdec.init_cache(self.cfg, tokens.shape[0], self.cache_len,
                                      enc=jnp.asarray(enc, self.cfg.dtype("compute")),
                                      params=self.params)
            logits = None
            pos = jnp.int32(0)
            for i in range(tokens.shape[1]):   # teacher-forced prompt feed
                logits, cache = self._decode(self.params, tokens[:, i:i + 1],
                                             cache, pos)
                pos = pos + 1
            return logits[:, 0], cache
        logits, cache = self._prefill(self.params, tokens,
                                      extras.get("patch_emb"))
        return logits, cache

    # -------------------------------------------------------------- decode
    def generate(self, prompt: jnp.ndarray, n_new: int, *,
                 temperature: float = 0.0, key=None, **extras
                 ) -> GenerationResult:
        B, S = prompt.shape
        logits, cache = self.prefill(prompt, **extras)
        pos = jnp.int32(S)
        out = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache, pos)
            pos = pos + 1
            tok = self._sample(logits[:, 0], temperature, key, i + 1)
        return GenerationResult(tokens=np.concatenate(out, axis=1),
                                steps=n_new, prefill_len=S)

    def _sample(self, logits: jnp.ndarray, temperature: float, key, i: int
                ) -> jnp.ndarray:
        logits = logits[:, : self.cfg.vocab_size]   # strip padded vocab
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key if key is not None else jax.random.key(0), i)
        return jax.random.categorical(
            k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


# ---------------------------------------------------------------------------
# continuous batching: slot pool + per-slot decode
# ---------------------------------------------------------------------------


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,)
    max_new: int
    done: bool = False
    output: Optional[np.ndarray] = None


@dataclass
class FinishedRequest:
    """One completed generation leaving the slot pool."""

    rid: int
    tokens: np.ndarray           # (n,) generated tokens (eos included)
    reason: str                  # "max_new" | "eos"
    slot: int


@dataclass
class _Slot:
    """Host-side bookkeeping of one live slot (the cache row is the
    device-side half)."""

    rid: int
    max_new: int
    tokens: List[int] = field(default_factory=list)   # emitted so far


class ContinuousEngine:
    """Fixed slot pool with per-slot insert / evict over one decode cache.

    The cache pytree is allocated once with batch axis ``n_slots``; a
    request occupies exactly one slot from insert to evict.  Decode is a
    per-slot ``vmap`` of the model's single-sequence ``decode_step``, so
    every slot carries its *own* cache position — mixed prompt lengths
    coexist without padding, and a freshly inserted slot starts decoding
    at its true prompt length while its neighbours continue uninterrupted.

    Invariants (tested):

    - **insert never clobbers a live slot** — inserting into an occupied
      slot (or a full pool) raises instead of overwriting;
    - **evict frees exactly one slot** — the evicted row is the only state
      that changes;
    - **slot independence** — a slot's decoded tokens are bit-identical
      whether or not a concurrent prefill-insert happened in another slot
      (``vmap`` rows only read their own cache row and position).

    Decoding is greedy (the deterministic mode every parity test and the
    router replay rely on); sampling stays on :class:`ServingEngine`.
    """

    def __init__(self, arch: Optional[Arch], params: Pytree, *,
                 n_slots: int = 4, cache_len: int = 1024,
                 use_smoke: bool = False, eos_id: Optional[int] = None,
                 cfg=None, module: Optional[str] = None):
        # arch is optional when cfg + module are given directly (the
        # training launcher serves preset configs that have no Arch)
        module = module if module is not None else arch.module
        if get_model_fns(module).prefill is None:
            raise ValueError(
                f"module {module!r} has no one-shot prefill; the slot "
                f"pool needs prefill -> insert (serve it with ServingEngine)")
        self.arch = arch
        self.module = module
        self.cfg = cfg if cfg is not None else (
            arch.smoke if use_smoke else arch.config)
        self.fns = get_model_fns(module)
        self.params = params
        self.n_slots = int(n_slots)
        self.cache_len = int(cache_len)
        self.eos_id = eos_id
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")

        cfg_ = self.cfg
        fns = self.fns

        self._prefill = jax.jit(
            lambda p, t: fns.prefill(p, cfg_, t, self.cache_len))
        # insert: write a solo-prefilled cache (batch 1) into slot row i of
        # the pool cache (batch n_slots) — the maxengine insert
        self._insert_row = jax.jit(lambda pool, one, slot: jax.tree.map(
            lambda P, o: jax.lax.dynamic_update_slice_in_dim(
                P, o.astype(P.dtype), slot, axis=1), pool, one))

        def _one(p, tok, cache, pos):
            # re-add a batch axis of 1: decode_step is written for (B, ...)
            cache1 = jax.tree.map(lambda x: x[:, None], cache)
            logits, nc = fns.decode_step(p, cfg_, tok[None], cache1, pos)
            return logits[0, 0], jax.tree.map(lambda x: x[:, 0], nc)

        def _step(p, toks, pool, pos):
            logits, pool = jax.vmap(_one, in_axes=(None, 0, 1, 0),
                                    out_axes=(0, 1))(p, toks, pool, pos)
            nxt = jnp.argmax(logits[:, : cfg_.vocab_size],
                             axis=-1).astype(jnp.int32)
            return nxt, pool

        self._step_fn = jax.jit(_step)

        self._pool = self.fns.init_cache(self.cfg, self.n_slots,
                                         self.cache_len)
        self.slots: List[Optional[_Slot]] = [None] * self.n_slots
        self._pos = np.zeros(self.n_slots, np.int32)
        self._tok = np.zeros((self.n_slots, 1), np.int32)
        self._finished: List[FinishedRequest] = []
        self.decode_steps = 0

    # ---------------------------------------------------------- occupancy
    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def live_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    # ------------------------------------------------------------- insert
    def insert(self, prompt: np.ndarray, max_new: int, *, rid: int = 0,
               slot: Optional[int] = None) -> int:
        """Prefill ``prompt`` solo and insert it into a free slot.

        Raises when the pool is full or the requested ``slot`` is live —
        inserting never clobbers in-flight state."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self.cache_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({max_new}) exceeds "
                f"cache_len ({self.cache_len})")
        if slot is None:
            free = self.free_slots
            if not free:
                raise RuntimeError("no free slot: evict (or wait for a "
                                   "finish) before inserting")
            slot = free[0]
        elif self.slots[slot] is not None:
            raise RuntimeError(f"slot {slot} is live (rid "
                               f"{self.slots[slot].rid}); insert refuses "
                               f"to clobber it")

        logits, cache = self._prefill(self.params, jnp.asarray(prompt)[None])
        first = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
        self._pool = self._insert_row(self._pool, cache, jnp.int32(slot))
        st = _Slot(rid=rid, max_new=int(max_new), tokens=[first])
        self.slots[slot] = st
        self._pos[slot] = prompt.size
        self._tok[slot, 0] = first
        self._maybe_finish(slot)
        return slot

    # -------------------------------------------------------------- decode
    def step(self) -> List[FinishedRequest]:
        """One batched decode step across the whole pool.

        Every live slot advances one token at its own position (free slots
        compute a throwaway row — the fixed pool shape is what keeps the
        compiled step cached).  Slots reaching ``max_new`` or ``eos_id``
        are evicted and returned (plus any insert-time finishes pending)."""
        if not self.live_slots:
            return self.take_finished()
        nxt, self._pool = self._step_fn(self.params, jnp.asarray(self._tok),
                                        self._pool, jnp.asarray(self._pos))
        nxt = np.array(nxt, np.int32)
        self.decode_steps += 1
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            tok = int(nxt[i])
            st.tokens.append(tok)
            self._pos[i] += 1
            self._tok[i, 0] = tok
            self._maybe_finish(i)
        return self.take_finished()

    def _maybe_finish(self, slot: int) -> None:
        st = self.slots[slot]
        if self.eos_id is not None and st.tokens[-1] == self.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.max_new:
            reason = "max_new"
        else:
            return
        self._finished.append(FinishedRequest(
            rid=st.rid, tokens=np.asarray(st.tokens, np.int32),
            reason=reason, slot=slot))
        self.evict(slot)

    def take_finished(self) -> List[FinishedRequest]:
        out, self._finished = self._finished, []
        return out

    # -------------------------------------------------------------- evict
    def evict(self, slot: int) -> None:
        """Free exactly one slot (the cache row is left in place — the next
        insert overwrites it wholesale)."""
        if self.slots[slot] is None:
            raise RuntimeError(f"slot {slot} is already free")
        self.slots[slot] = None
        self._pos[slot] = 0
        self._tok[slot, 0] = 0


# ---------------------------------------------------------------------------
# request-level scheduling
# ---------------------------------------------------------------------------


class ContinuousScheduler:
    """Continuous-batching front: decoupled prefill and decode queues.

    ``submit`` enqueues onto the *prefill* queue; the run loop admits at
    most one prefill-insert per decode step, so a burst of long prompts is
    absorbed one slot at a time while every in-flight request keeps
    decoding at full cadence.  ``history`` records the interleaving
    (``("prefill", rid, slot)`` / ``("decode", n_live)`` /
    ``("finish", rid, reason)``) — the request-lifecycle trace
    `docs/serving.md` walks through."""

    def __init__(self, engine: ContinuousEngine):
        self.engine = engine
        self.queue: deque[Request] = deque()
        self.results: Dict[int, np.ndarray] = {}
        self.history: List[Tuple] = []
        self._rid = 0

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  int(max_new)))
        return rid

    @property
    def pending(self) -> int:
        return len(self.queue)

    def _drain(self, finished: List[FinishedRequest]) -> None:
        for f in finished:
            self.results[f.rid] = f.tokens
            self.history.append(("finish", f.rid, f.reason))

    def step(self) -> bool:
        """One scheduler iteration: at most one prefill-insert, then one
        pool decode step.  Returns False when fully idle."""
        if self.queue and self.engine.free_slots:
            req = self.queue.popleft()
            slot = self.engine.insert(req.prompt, req.max_new, rid=req.rid)
            self.history.append(("prefill", req.rid, slot))
            self._drain(self.engine.take_finished())
        if self.engine.live_slots:
            self.history.append(("decode", len(self.engine.live_slots)))
            self._drain(self.engine.step())
        return bool(self.queue or self.engine.live_slots)

    def run(self) -> Dict[int, np.ndarray]:
        while self.step():
            pass
        return self.results


class BatchScheduler:
    """Run-to-completion baseline batcher: fills a group of ``batch_size``
    slots, decodes the whole group until every member finishes, then admits
    the next group.  Requests are prefilled solo through the same slot pool
    as :class:`ContinuousScheduler` — no padding, so batched output is
    token-for-token identical to solo generation; what this scheduler
    keeps from its ancestor is the *head-of-line blocking* that
    `benchmarks/serving.py` measures continuous batching against."""

    def __init__(self, engine: ServingEngine, batch_size: int):
        self.engine = engine
        self.batch_size = int(batch_size)
        self.queue: List[Request] = []
        self._pool = ContinuousEngine(
            engine.arch, engine.params, n_slots=self.batch_size,
            cache_len=engine.cache_len, cfg=engine.cfg)

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = len(self.queue)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  int(max_new)))
        return rid

    def run(self) -> Dict[int, np.ndarray]:
        results: Dict[int, np.ndarray] = {}
        pending = [r for r in self.queue if not r.done]
        for i in range(0, len(pending), self.batch_size):
            group = pending[i:i + self.batch_size]
            for r in group:
                self._pool.insert(r.prompt, r.max_new, rid=r.rid)
            finished = self._pool.take_finished()
            while self._pool.live_slots:
                finished += self._pool.step()
            for f in finished:
                req = next(r for r in group if r.rid == f.rid)
                req.done = True
                req.output = f.tokens[: req.max_new]
                results[f.rid] = req.output
        return results
