"""Geo-distributed trainer: per-pod vmapped step + sync-strategy integration.

The trainer is generic over a ``loss_fn(params, batch) -> (loss, metrics)``:
the LLM path wraps ``repro.models.transformer.loss_fn`` with its ModelConfig,
and the paper-reproduction path passes the reference models' losses directly.

State layout: every leaf of ``params`` / ``opt_state`` / ``ga_buffer`` (and
the WAN codec's flat ``ef_residual`` error-feedback buffer) has a leading
**pod** dimension (size ``n_pods`` — the number of cloud partitions).
On a multi-pod mesh that dimension is sharded over the ``"pod"`` axis; on a
single CPU device it emulates the clouds faithfully (same numerics).  The
per-pod step is ``vmap``-ed over it; the sync strategies act on it with
roll/mean (-> collective-permute / all-reduce on TPU).

Host loop responsibilities (the physical-training-plane workflow of the
paper): feed per-pod batches (possibly uneven via masking — the elastic
scheduler's batch split), call the jitted ``train_step`` every iteration and
the jitted ``sync_step`` at the strategy's sync points, account WAN traffic,
and terminate (scale-to-zero) when the local stop condition fires.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sync import (SyncConfig, SyncState, _chunk_widths,
                             apply_sync, bucket_chunk_mb, bucket_layout,
                             bucket_weights_of, bucket_wire_mb,
                             finish_codec_sync, finish_codec_sync_split,
                             grow_pods, init_sync_state, is_sync_step,
                             on_step_gradients, prepare_codec_sync,
                             reencode_unsent, resize_sync_state,
                             retune_sync_state, ship_sync_payloads,
                             shrink_pods, traffic_per_step_mb)
from repro.optim.optimizers import (Optimizer, clip_by_global_norm,
                                    constant_schedule, get_optimizer,
                                    global_norm)

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt_state: Pytree
    sync_state: SyncState
    step: jnp.ndarray


@dataclass(frozen=True)
class TrainerConfig:
    n_pods: int = 1
    optimizer: str = "sgd"
    optimizer_kwargs: tuple = ()
    lr: float = 0.05
    lr_schedule: Optional[Callable] = None
    clip_norm: float = 0.0
    sync: SyncConfig = field(default_factory=SyncConfig)

    def make_optimizer(self) -> Optimizer:
        return get_optimizer(self.optimizer, **dict(self.optimizer_kwargs))

    def make_schedule(self):
        return self.lr_schedule or constant_schedule(self.lr)


class Trainer:
    def __init__(self, loss_fn: Callable, init_fn: Callable,
                 cfg: TrainerConfig, transport=None, stream=None):
        """loss_fn(params, batch) -> (loss, metrics dict);
        init_fn(key) -> params (single-pod, unstacked).

        ``transport`` selects who ships sync payloads
        (:mod:`repro.core.transport`): ``None`` keeps the legacy inline
        ring traced into the jitted sync step (bit-exact).  An in-graph
        transport (``SimTransport``) also ships inside that one jit and is
        billed host-side at the round barrier; a host-seam transport
        (``MeshTransport``) switches the codec sync to the split path —
        jitted prepare, host-timed per-bucket ship, jitted finish — so
        each bucket's transfer time is measured on-host.

        ``stream`` (a :class:`repro.core.autotune.StreamingShipController`)
        turns sync rounds chunk-granular on streaming-capable transports:
        jitted prepare, then per-chunk host-seam ship with the chunk's
        measured transfer observed AS IT LANDS — and, on a mid-round
        bandwidth cliff, a one-shot re-encode of the round's unsent
        segments at a cheaper ladder rung (``sync.reencode_unsent`` /
        ``finish_codec_sync_split``; the EF residual carries the fidelity
        delta exactly).  A round with zero retunes is bit-identical to
        the non-streaming path."""
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.cfg = cfg
        self.transport = transport
        self.stream = stream
        self._host_seam = (transport is not None
                           and not getattr(transport, "in_graph", True))
        self.optimizer = cfg.make_optimizer()
        self.schedule = cfg.make_schedule()
        self._train_step = jax.jit(self._train_step_impl)
        self._sync_step = jax.jit(self._sync_step_impl)
        self._prepare_sync = jax.jit(self._prepare_sync_impl)
        self._finish_sync = jax.jit(self._finish_sync_impl)
        self._finish_sync_masked = jax.jit(self._finish_sync_masked_impl)
        # compiled-sync-step cache across retunes, keyed by the codec
        # shape of the config (interval is host-side scheduling only and
        # never forces a re-jit); carried from trainer to trainer so an
        # adaptive controller revisiting a rung reuses the old executable.
        # The host-seam split path caches its (prepare, finish, masked
        # finish) triple under the same key discipline.
        self._sync_cache: Dict[SyncConfig, Any] = {self._sync_key(cfg.sync):
                                                   self._sync_step}
        self._split_cache: Dict[SyncConfig, Any] = {
            self._sync_key(cfg.sync): (self._prepare_sync,
                                       self._finish_sync,
                                       self._finish_sync_masked)}
        # streaming retune path: (from-key, to-key, sent-signature) ->
        # (jitted tail re-encode, jitted split finish).  The partial-round
        # split point is part of the key — a re-encode that aborts after a
        # different chunk is a different program
        self._stream_cache: Dict[Tuple, Any] = {}
        self._bucket_weights: Optional[Dict[str, float]] = None
        self._wire_mb: Optional[Dict[str, float]] = None
        self._chunk_mb: Optional[Dict[str, Tuple[float, ...]]] = None
        self.traffic_mb = 0.0
        self.stream_retunes = 0

    @staticmethod
    def _sync_key(sync: SyncConfig) -> SyncConfig:
        """Cache key: the jitted sync step depends on every codec knob —
        per-bucket tiers/fractions included — but NOT on the interval."""
        import dataclasses
        return dataclasses.replace(sync, interval=1)

    def bucket_weights(self, state: "TrainState") -> Optional[Dict[str, float]]:
        """Per-bucket model-element fractions (memoized; shape-only), for
        exact layer-class traffic accounting."""
        if self.cfg.sync.bucket_policy == "single":
            return None
        if self._bucket_weights is None:
            self._bucket_weights = bucket_weights_of(self.cfg.sync,
                                                     state.params)
        return self._bucket_weights

    # ------------------------------------------------------------- state
    def init_state(self, key, same_init: bool = True) -> TrainState:
        """Stacked initial state.  ``same_init=True`` gives all pods identical
        initial parameters (the paper's setup: one model replicated)."""
        n = self.cfg.n_pods
        if same_init:
            p0 = self.init_fn(key)
            params = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), p0)
        else:
            keys = jax.random.split(key, n)
            params = jax.vmap(self.init_fn)(keys)
        opt_state = jax.vmap(self.optimizer.init)(params)
        return TrainState(
            params=params,
            opt_state=opt_state,
            sync_state=init_sync_state(self.cfg.sync, params),
            step=jnp.zeros((), jnp.int32),
        )

    # -------------------------------------------------------------- steps
    def _train_step_impl(self, state: TrainState, batch: Pytree
                         ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        lr = self.schedule(state.step)

        grad_fn = jax.value_and_grad(self.loss_fn, has_aux=True)
        (loss, metrics), grads = jax.vmap(grad_fn)(state.params, batch)

        if self.cfg.clip_norm > 0:
            grads = jax.vmap(
                lambda g: clip_by_global_norm(g, self.cfg.clip_norm))(grads)

        grads, sync_state = on_step_gradients(self.cfg.sync, grads,
                                              state.sync_state)

        new_params, new_opt = jax.vmap(
            self.optimizer.update, in_axes=(0, 0, 0, None)
        )(grads, state.opt_state, state.params, lr)

        out_metrics = {"loss": jnp.mean(loss), "loss_per_pod": loss,
                       "grad_norm": jax.vmap(global_norm)(grads), "lr": lr}
        for k, v in metrics.items():
            if k not in ("loss",):
                out_metrics[k] = jnp.mean(v)
        return TrainState(new_params, new_opt, sync_state,
                          state.step + 1), out_metrics

    def _sync_step_impl(self, state: TrainState) -> TrainState:
        lr = self.schedule(state.step)
        transport = (self.transport if (self.transport is not None
                                        and self.transport.in_graph)
                     else None)
        params, sync_state = apply_sync(self.cfg.sync, state.params,
                                        state.sync_state, lr,
                                        transport=transport)
        return state._replace(params=params, sync_state=sync_state)

    # ------------------------------------------ host-seam (split) sync path
    def _prepare_sync_impl(self, state: TrainState):
        return prepare_codec_sync(self.cfg.sync, state.sync_state)

    def _finish_sync_impl(self, state: TrainState, payloads, shipped
                          ) -> TrainState:
        lr = self.schedule(state.step)
        params, sync_state = finish_codec_sync(
            self.cfg.sync, state.params, state.sync_state, payloads,
            shipped, lr)
        return state._replace(params=params, sync_state=sync_state)

    def _finish_sync_masked_impl(self, state: TrainState, payloads, shipped,
                                 alive) -> TrainState:
        """Degraded-round finish: complete the round over the surviving
        membership mask (``alive`` is a traced argument, so one compile
        covers every crash pattern).  See ``finish_codec_sync``'s mask
        semantics: undelivered messages stay whole in the EF residual and
        the dead rows' telemetry zeroes out."""
        lr = self.schedule(state.step)
        params, sync_state = finish_codec_sync(
            self.cfg.sync, state.params, state.sync_state, payloads,
            shipped, lr, alive=alive)
        return state._replace(params=params, sync_state=sync_state)

    def wire_mb(self, state: TrainState) -> Dict[str, float]:
        """Per-bucket per-pod wire MB of one sync round (memoized per
        config; shape-only host arithmetic) — what transports bill."""
        if self._wire_mb is None:
            layout = bucket_layout(self.cfg.sync,
                                   state.sync_state.ga_buffer)
            self._wire_mb = bucket_wire_mb(self.cfg.sync, layout)
        return self._wire_mb

    def chunk_mb(self, state: TrainState) -> Dict[str, Tuple[float, ...]]:
        """Per-chunk wire MB of each bucket (memoized per config) — the
        streaming ship's chunk schedule."""
        if self._chunk_mb is None:
            layout = bucket_layout(self.cfg.sync,
                                   state.sync_state.ga_buffer)
            self._chunk_mb = bucket_chunk_mb(self.cfg.sync, layout)
        return self._chunk_mb

    # ------------------------------------------------ streaming sync path
    def _can_stream(self) -> bool:
        return (self.stream is not None
                and self.cfg.sync.uses_codec
                and self.transport is not None
                and getattr(self.transport, "supports_streaming", False))

    def _stream_fns(self, state: TrainState, cfg_to: SyncConfig,
                    sent: Dict[str, int]):
        """Jitted (tail re-encode, split finish) pair for one retune shape,
        cached under the split-path key: (from config, to config, where
        each bucket's schedule was cut)."""
        sent_key = tuple(sorted(sent.items()))
        key = (self._sync_key(self.cfg.sync), self._sync_key(cfg_to),
               sent_key)
        fns = self._stream_cache.get(key)
        if fns is None:
            cfg = self.cfg.sync
            layout = bucket_layout(cfg, state.sync_state.ga_buffer)
            sent_d = dict(sent)

            def reenc(flat):
                return reencode_unsent(cfg, cfg_to, flat, layout, sent_d)

            def fin(st, payloads, shipped, tail_shipped, tail_local):
                lr = self.schedule(st.step)
                params, sync_state = finish_codec_sync_split(
                    cfg, cfg_to, st.params, st.sync_state, payloads,
                    shipped, tail_shipped, tail_local, sent_d, lr)
                return st._replace(params=params, sync_state=sync_state)

            fns = (jax.jit(reenc), jax.jit(fin))
            self._stream_cache[key] = fns
        return fns

    def _stream_sync(self, state: TrainState,
                     host_step: int) -> Optional[TrainState]:
        """One chunk-granular sync round.  Returns None when the transport
        declines the streaming protocol for this round (e.g. a chaos plan
        armed a fault — the classic retry/degrade path must run instead).

        The round: jitted prepare at the live config; per-chunk host-seam
        ship, each landed chunk observed by the StreamingShipController
        against the pre-round bandwidth belief; on a cliff, ONE transient
        retune — the unsent segments re-encode at the cheaper rung, the
        transport re-prices the tail at the current bandwidth, and the
        split finish splices prefix + tail so the EF residual carries the
        tail's fidelity delta exactly.  ``end_stream_round`` then emits
        the same records/probe fold ``on_sync`` would — bit-identical when
        no retune fired."""
        from repro.core.autotune import BucketStats

        cfg = self.cfg.sync
        wire = self.wire_mb(state)
        if not self.transport.begin_stream_round(wire, step=host_step):
            return None
        self.stream.note_stats(BucketStats.from_sync_state(state.sync_state))
        self.stream.begin_round(host_step, cfg)
        payloads = self._prepare_sync(state)
        chunk_mb = self.chunk_mb(state)
        shipped: Dict[str, List] = {}
        # every bucket starts at 0 sent chunks: when a retune aborts the
        # schedule, buckets not yet reached re-encode whole
        sent: Dict[str, int] = {name: 0 for name in payloads.chunks}
        cfg_to: Optional[SyncConfig] = None
        for name, bchunks in payloads.chunks.items():
            for i, chunk in enumerate(bchunks):
                out, secs = self.transport.stream_ship_chunk(
                    name, chunk, cfg.peer_shift, chunk_mb[name][i])
                shipped.setdefault(name, []).append(out)
                sent[name] = i + 1
                cfg_to = self.stream.observe_chunk(name, chunk_mb[name][i],
                                                   secs)
                if cfg_to is not None:
                    break
            if cfg_to is not None:
                break
        shipped_t = {n: tuple(c) for n, c in shipped.items()}
        tails = {}
        if cfg_to is not None:
            reenc, fin = self._stream_fns(state, cfg_to, sent)
            tails, tail_local = reenc(payloads.flat)
        if tails:
            # price the re-encoded tail as one fresh transfer at the
            # *current* bandwidth, then stream it out chunk by chunk
            layout = bucket_layout(cfg, state.sync_state.ga_buffer)
            tail_schedule: Dict[str, Tuple[float, ...]] = {}
            for g, name in enumerate(layout.names):
                if name not in tails:
                    continue
                size = layout.sizes[g]
                widths = _chunk_widths(cfg.for_bucket(name), size)
                sw = int(sum(widths[:sent.get(name, 0)]))
                tcfg = cfg_to.for_bucket(name)
                tail_schedule[name] = tuple(
                    tcfg.payload_mb(m * 4 / 1e6)
                    for m in _chunk_widths(tcfg, size - sw))
            self.transport.retune_stream(
                sum(mb for t in tail_schedule.values() for mb in t))
            self.stream_retunes += 1
            tail_shipped: Dict[str, List] = {}
            for name, tchunks in tails.items():
                for i, chunk in enumerate(tchunks):
                    out, secs = self.transport.stream_ship_chunk(
                        name, chunk, cfg.peer_shift,
                        tail_schedule[name][i])
                    tail_shipped.setdefault(name, []).append(out)
                    self.stream.observe_chunk(name,
                                              tail_schedule[name][i], secs)
            state = fin(state, payloads, shipped_t,
                        {n: tuple(c) for n, c in tail_shipped.items()},
                        tail_local)
        else:
            state = self._finish_sync(state, payloads, shipped_t)
        self.transport.end_stream_round()
        self.stream.end_round()
        return state

    def _host_sync(self, state: TrainState) -> TrainState:
        """Codec sync as three dispatches with the transport at the seam:
        the ship runs host-side so the transport can execute and time each
        bucket's transfer (the measured feedback MeshTransport reports).
        Numerically identical to the monolithic jitted sync step — the
        three stages are the same functions apply_sync composes."""
        payloads = self._prepare_sync(state)
        shipped = ship_sync_payloads(self.cfg.sync, payloads.chunks,
                                     self.transport, self.wire_mb(state))
        failed = tuple(getattr(self.transport, "round_failed_pods", ()) or ())
        if failed:
            alive = np.ones((self.cfg.n_pods,), np.float32)
            for p in failed:
                if 0 <= p < self.cfg.n_pods:
                    alive[p] = 0.0
            return self._finish_sync_masked(state, payloads, shipped,
                                            jnp.asarray(alive))
        return self._finish_sync(state, payloads, shipped)

    def train_step(self, state, batch):
        return self._train_step(state, batch)

    # ------------------------------------------------------ elasticity
    def reconfigure(self, state: TrainState, n_pods: int,
                    keep: Optional[Tuple[int, ...]] = None,
                    sync: Optional[SyncConfig] = None
                    ) -> Tuple["Trainer", TrainState]:
        """Apply a reconfiguration at a sync barrier: re-stack the leading pod
        dimension of the whole train state (grow: mean-seeded joiners; shrink:
        departed pods re-averaged into survivors, gradient accumulators
        replay-accumulated) and return a fresh ``Trainer`` bound to the new
        pod count / sync config, with WAN-traffic accounting carried over."""
        import dataclasses
        new_cfg = dataclasses.replace(self.cfg, n_pods=n_pods,
                                      sync=sync or self.cfg.sync)
        new_state = resize_train_state(new_cfg.sync, state, n_pods, keep=keep)
        trainer = Trainer(self.loss_fn, self.init_fn, new_cfg,
                          transport=self.transport, stream=self.stream)
        trainer.traffic_mb = self.traffic_mb
        trainer.stream_retunes = self.stream_retunes
        return trainer, new_state

    def retune(self, state: TrainState, sync: SyncConfig
               ) -> Tuple["Trainer", TrainState]:
        """Apply an adaptive-sync retune (``SyncPlanUpdate.sync``) at a sync
        barrier: same strategy and pod count, different codec tier / top-k /
        interval.  Unlike :meth:`reconfigure` nothing is re-stacked — params
        and optimizer state pass through untouched, and the EF residual
        carries over (it lives in dense bucket coordinates, so its meaning
        is tier-independent); only the jitted sync step re-compiles."""
        import dataclasses
        new_cfg = dataclasses.replace(self.cfg, sync=sync)
        sync_state = retune_sync_state(sync, self.cfg.sync, state.sync_state,
                                       state.params)
        trainer = Trainer(self.loss_fn, self.init_fn, new_cfg,
                          transport=self.transport, stream=self.stream)
        # the per-step path depends on the sync *strategy* (which a retune
        # cannot change), not the codec knobs — reuse the compiled train
        # step so a retune recompiles only the sync step.  And only when a
        # bucket's tier/top-k actually changed: the shared sync-step cache
        # (keyed on the interval-normalized config) means an interval-only
        # retune, or a return to a previously compiled rung combination,
        # re-jits nothing at all.  The host-seam (prepare, finish) pair
        # follows the same cache discipline.
        trainer._train_step = self._train_step
        trainer._sync_cache = self._sync_cache
        trainer._split_cache = self._split_cache
        trainer._stream_cache = self._stream_cache
        trainer.stream_retunes = self.stream_retunes
        key = self._sync_key(sync)
        cached = self._sync_cache.get(key)
        if cached is not None:
            trainer._sync_step = cached
        else:
            self._sync_cache[key] = trainer._sync_step
        split_cached = self._split_cache.get(key)
        if split_cached is not None:
            (trainer._prepare_sync, trainer._finish_sync,
             trainer._finish_sync_masked) = split_cached
        else:
            self._split_cache[key] = (trainer._prepare_sync,
                                      trainer._finish_sync,
                                      trainer._finish_sync_masked)
        if sync.bucket_policy == self.cfg.sync.bucket_policy:
            trainer._bucket_weights = self._bucket_weights
        trainer.traffic_mb = self.traffic_mb
        return trainer, state._replace(sync_state=sync_state)

    def maybe_sync(self, state: TrainState, host_step: int,
                   model_mb: float = 0.0) -> TrainState:
        if self.cfg.n_pods > 1:
            # WAN transfers per sync round: the flat ring's count is one
            # per pod; a hierarchical transport exposes its compiled
            # schedule's count (tree over R regions: 2(R-1); auxiliary
            # routes pay both hops) — same multiplier cost.adaptive_traffic_mb
            # bills and the DES charges
            legs = getattr(self.transport, "wan_transfers_per_round", None)
            self.traffic_mb += traffic_per_step_mb(
                self.cfg.sync, model_mb,
                bucket_weights=self.bucket_weights(state)) * (
                    legs if legs is not None else self.cfg.n_pods)
        if is_sync_step(self.cfg.sync, host_step) and self.cfg.n_pods > 1:
            # fault-aware transports arm their plan per round (which pods
            # are dead, which transfers will need retries) before shipping
            begin = getattr(self.transport, "begin_round", None)
            if begin is not None:
                begin(host_step)
            if self._can_stream():
                streamed = self._stream_sync(state, host_step)
                if streamed is not None:
                    # the streaming round already billed itself
                    # (end_stream_round IS this round's barrier)
                    return streamed
            if self._host_seam and self.cfg.sync.uses_codec:
                state = self._host_sync(state)
            else:
                state = self._sync_step(state)
            if self.transport is not None:
                # round barrier: bill (sim) or flush (mesh) this round's
                # transfers into the transport's records + measured probe
                self.transport.on_sync(self.wire_mb(state), step=host_step)
        return state

    # --------------------------------------------------------------- loop
    def fit(self, state: TrainState, batches: Callable[[int], Pytree],
            n_steps: int, *, eval_fn: Optional[Callable] = None,
            eval_every: int = 0, model_mb: float = 0.0,
            log_every: int = 0) -> Tuple[TrainState, Dict[str, List]]:
        """batches(step) -> stacked per-pod batch pytree (n_pods leading)."""
        history: Dict[str, List] = {"step": [], "loss": [], "loss_per_pod": [],
                                    "eval": []}
        for step in range(n_steps):
            batch = batches(step)
            state, metrics = self.train_step(state, batch)
            state = self.maybe_sync(state, step, model_mb)
            history["step"].append(step)
            history["loss"].append(float(metrics["loss"]))
            history["loss_per_pod"].append(
                np.asarray(metrics["loss_per_pod"]).tolist())
            if eval_fn and eval_every and (step + 1) % eval_every == 0:
                history["eval"].append((step, eval_fn(state)))
            if log_every and (step + 1) % log_every == 0:
                print(f"step {step + 1}: loss={history['loss'][-1]:.4f}")
        return state, history


# ---------------------------------------------------------------------------
# elasticity: pod re-stacking of the train state
# ---------------------------------------------------------------------------


def resize_train_state(sync_cfg: SyncConfig, state: TrainState, n_new: int,
                       keep: Optional[Tuple[int, ...]] = None) -> TrainState:
    """Grow/shrink the leading pod dimension of a :class:`TrainState`.

    ``keep`` names the surviving old pod indices in their new order (defaults
    to the first ``min(old, new)`` pods).  Parameters use mean-preserving
    transforms; optimizer moments are mean-seeded on grow but plainly kept on
    shrink (no shift — Adam's second moment must stay non-negative); the sync
    state follows its strategy's semantics — the ASGD-GA gradient buffer and
    the codec's error-feedback residual both replay-accumulate on shrink
    (sum-preserving) and zero-seed joiners
    (see ``repro.core.sync.resize_sync_state``).
    """
    n_old = jax.tree.leaves(state.params)[0].shape[0]
    if keep is None:
        keep = tuple(range(min(n_old, n_new)))
    if len(keep) > n_new:
        raise ValueError(f"keep={keep} longer than n_new={n_new}")
    shrunk = len(keep) < n_old
    params, opt = state.params, state.opt_state
    if shrunk:
        params = shrink_pods(params, keep, how="mean")
        # survivors keep their own optimizer moments untouched: a mean shift
        # could push sign-constrained leaves (Adam's second moment) negative
        opt = shrink_pods(opt, keep, how="drop")
    if n_new > len(keep):
        params = grow_pods(params, n_new, how="mean")
        opt = grow_pods(opt, n_new, how="mean")
    sync_state = resize_sync_state(sync_cfg, state.sync_state, params,
                                   keep=keep if shrunk else None)
    return TrainState(params=params, opt_state=opt, sync_state=sync_state,
                      step=state.step)


def apply_reconfig(trainer: Trainer, state: TrainState, reconfig
                   ) -> Tuple[Trainer, TrainState, bool]:
    """Bridge a control-plane :class:`~repro.core.control_plane.ReconfigPlan`
    onto a live trainer.  Returns ``(trainer, state, applied)`` — an empty
    plan diff is a structural no-op and leaves both untouched."""
    if reconfig.is_noop:
        return trainer, state, False
    keep, n_new = reconfig.pod_transition()
    new_trainer, new_state = trainer.reconfigure(
        state, n_new, keep=keep, sync=reconfig.new.request.sync)
    return new_trainer, new_state, True


# ---------------------------------------------------------------------------
# elasticity: live pod migration off the step path
# ---------------------------------------------------------------------------


def _resized_like(tree: Pytree, n_old: int, n_new: int) -> Pytree:
    """Shape/dtype skeleton of ``tree`` with every pod-stacked leaf's
    leading dimension re-sized ``n_old -> n_new`` (scalar bookkeeping
    leaves pass through)."""
    def f(x):
        shape = tuple(getattr(x, "shape", ()))
        if len(shape) >= 1 and shape[0] == n_old:
            shape = (n_new,) + shape[1:]
        return jax.ShapeDtypeStruct(shape, x.dtype)
    return jax.tree.map(f, tree)


class LiveMigrator:
    """Live pod migration: a grow/shrink staged off the training step.

    On a ``PlanDiff`` the surviving pods keep stepping.  :meth:`stage`
    materializes the target-pod-count state skeleton from the async
    engine's last durable snapshot on a background thread, via the
    checkpoint layer's ``pod_resize`` transforms — in a real deployment
    this is the bulk WAN shipment of the migration (the
    ``migration_wire_mb`` bytes the DES bills as overlapped background
    traffic).  At the next sync barrier :meth:`reconcile` applies the same
    pod-resize transforms to the *live* state (``apply_reconfig`` /
    ``resize_train_state`` — EF residuals and optimizer moments carried
    under exactly the invariants ``retune_sync_state`` guarantees), so the
    reconciled state is bit-identical to a pause-and-restore taken at the
    barrier; the staged restore validates the target structure and stands
    by as the recovery base if the barrier never comes (pod crash
    mid-migration).  The reconfiguration's only stall is the one barrier
    it reconciles at."""

    def __init__(self, engine):
        import threading
        self.engine = engine
        self._threading = threading
        self._pending: Optional[Tuple[Any, Dict[str, Any]]] = None
        self.migrations = 0
        self.restaged = 0
        self.staged_mb = 0.0
        self.errors: List[Exception] = []
        self.last_staged: Optional[Dict[str, Any]] = None

    @property
    def pending(self) -> bool:
        return self._pending is not None

    def stage(self, state: TrainState, n_new: int,
              keep: Optional[Tuple[int, ...]] = None) -> None:
        """Start materializing the ``n_new``-pod state from the last
        durable snapshot in the background.  Supersedes any earlier
        un-reconciled stage (the launcher composes events between
        barriers — only the barrier-time plan is reconciled)."""
        from repro.checkpoint import checkpoint as _ckpt

        if self._pending is not None:
            self._join_pending(superseded=True)
        n_old = jax.tree.leaves(state.params)[0].shape[0]
        like = _resized_like(state, n_old, n_new)
        holder: Dict[str, Any] = {"n_new": n_new,
                                  "keep": tuple(keep) if keep else None}

        def work():
            try:
                self.engine.wait()
                durable = self.engine.last_durable()
                if durable is None:
                    return
                snap_step, path = durable
                staged, ckpt_step = _ckpt.restore(path, like=like,
                                                  pod_resize="mean")
                holder.update(
                    state=staged, snapshot_step=snap_step,
                    ckpt_step=ckpt_step,
                    mb=sum(np.asarray(x).nbytes
                           for x in jax.tree.leaves(staged.params)) / 1e6)
            except Exception as e:   # noqa: BLE001 — surfaced at reconcile
                holder["error"] = e

        t = self._threading.Thread(target=work, daemon=True,
                                   name="live-migrator")
        t.start()
        self._pending = (t, holder)

    def _join_pending(self, superseded: bool = False) -> Optional[Dict]:
        t, holder = self._pending
        t.join()
        self._pending = None
        err = holder.get("error")
        if err is not None:
            # a failed stage degrades to a plain barrier re-stack — the
            # reconcile math never depended on the staged bytes
            self.errors.append(err)
            return None
        if superseded:
            self.restaged += 1
            return None
        if "state" not in holder:
            return None   # no durable snapshot yet: nothing was staged
        return holder

    def reconcile(self, trainer: Trainer, state: TrainState, reconfig
                  ) -> Tuple[Trainer, TrainState, bool]:
        """At the sync barrier: reconcile the migration against the live
        state.  Same signature and semantics as :func:`apply_reconfig` —
        and bit-identical results: the staged snapshot never enters the
        numerics, it only pre-moved the bytes a joining/leaving pod needs
        and pre-validated the target structure."""
        staged = self._join_pending() if self._pending is not None else None
        new_trainer, new_state, applied = apply_reconfig(trainer, state,
                                                         reconfig)
        if not applied:
            return new_trainer, new_state, applied
        self.migrations += 1
        if staged is not None:
            if staged["n_new"] != new_trainer.cfg.n_pods:
                # the plan evolved between stage and barrier: the staged
                # skeleton is stale — the barrier re-stack covered it
                self.restaged += 1
            else:
                ref = jax.tree.leaves(new_state.params)
                got = jax.tree.leaves(staged["state"].params)
                if [(tuple(a.shape), a.dtype) for a in got] != \
                        [(tuple(a.shape), a.dtype) for a in ref]:
                    raise RuntimeError(
                        "staged migration skeleton does not match the "
                        "reconciled state — snapshot/plan divergence")
                self.staged_mb += staged["mb"]
                self.last_staged = staged
        return new_trainer, new_state, applied


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def stack_pod_batches(batches: List[Dict[str, np.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Stack per-cloud host batches (padding uneven batch sizes with masked
    examples so the elastic scheduler's uneven splits fit the stacked shape)."""
    max_b = max(len(next(iter(b.values()))) for b in batches)
    out: Dict[str, List[np.ndarray]] = {}
    for b in batches:
        n = len(next(iter(b.values())))
        pad = max_b - n
        mask = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
        for k, v in b.items():
            if pad:
                v = np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
            out.setdefault(k, []).append(v)
        out.setdefault("example_mask", []).append(mask)
    return {k: jnp.asarray(np.stack(v)) for k, v in out.items()}


def accuracy_eval(apply_fn, data: Dict[str, np.ndarray], batch: int = 512):
    """Eval callback: mean accuracy of pod-0's model on held-out data."""

    @jax.jit
    def acc(params, x, y):
        logits = apply_fn(params, x)
        if logits.ndim == 1:   # binary (DeepFM)
            return jnp.mean((logits > 0).astype(jnp.int32) == y)
        return jnp.mean(jnp.argmax(logits, -1) == y)

    def fn(state: TrainState) -> float:
        p0 = jax.tree.map(lambda x: x[0], state.params)
        n = len(data["y"])
        accs = []
        for i in range(0, n, batch):
            accs.append(float(acc(p0, data["x"][i:i + batch],
                                  data["y"][i:i + batch])))
        return float(np.mean(accs))

    return fn
